"""Model registry: one uniform bundle per architecture family.

Every assigned architecture resolves to a ``ModelBundle`` exposing:

  init(key) -> params
  loss(params, batch) -> (loss, metrics)              [train_4k]
  prefill(params, batch, cache_len, window) -> (logits, cache)
  decode(params, cache, tokens, lengths, window) -> (logits, cache)
  empty_cache(batch, cache_len, dtype) -> cache pytree
  batch_shapes(mode, batch, seq) -> {name: ShapeDtypeStruct}

``batch_shapes`` is the dry-run contract: weak-type-correct stand-ins
for every model input, no allocation (MULTI-POD DRY-RUN step 2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import encdec, hybrid, lm, ssm, vlm
from .common import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    empty_cache: Callable
    batch_shapes: Callable

    def make_batch(self, rng: np.random.Generator, mode: str, batch: int,
                   seq: int) -> Dict[str, jnp.ndarray]:
        """Concrete random inputs matching batch_shapes (smoke tests)."""
        out = {}
        for name, s in self.batch_shapes(mode, batch, seq).items():
            if jnp.issubdtype(s.dtype, jnp.integer):
                if name == "lengths":
                    arr = rng.integers(1, seq, s.shape)
                else:
                    arr = rng.integers(0, self.cfg.vocab, s.shape)
            else:
                arr = rng.normal(0, 1, s.shape)
            out[name] = jnp.asarray(arr, s.dtype)
        return out


def _tok_shapes(cfg, mode, batch, seq):
    if mode == "train":
        return {"tokens": SDS((batch, seq), jnp.int32),
                "labels": SDS((batch, seq), jnp.int32)}
    if mode == "prefill":
        return {"tokens": SDS((batch, seq), jnp.int32)}
    return {"tokens": SDS((batch, 1), jnp.int32),
            "lengths": SDS((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# per-family bundles
# ---------------------------------------------------------------------------

def _dense_bundle(cfg: ModelConfig) -> ModelBundle:
    def prefill(params, batch, cache_len=None, window=None,
                data_shards=16):
        # n_valid/moe_cap: capacity-stable bucketed-MoE scalars the
        # serving engine puts in the batch (traced values, see
        # lm.moe_dispatch); absent for exact-length/non-moe prefill
        return lm.lm_prefill(params, cfg, batch["tokens"], cache_len,
                             window=window, data_shards=data_shards,
                             n_valid=batch.get("n_valid"),
                             moe_cap=batch.get("moe_cap"))

    def decode(params, cache, tokens, lengths, window=None,
               data_shards=16):
        return lm.lm_decode(params, cfg, cache, tokens, lengths,
                            data_shards=data_shards)

    def empty_cache(batch, cache_len, dtype):
        L = cfg.n_layers
        return {"k": jnp.zeros((L, batch, cfg.n_kv_heads, cache_len,
                                cfg.dh), dtype),
                "v": jnp.zeros((L, batch, cfg.n_kv_heads, cache_len,
                                cfg.dh), dtype)}

    return ModelBundle(
        cfg=cfg,
        init=lambda key: lm.init_lm(key, cfg),
        loss=lambda params, batch, **kw: lm.lm_loss(params, cfg, batch,
                                                    **kw),
        prefill=prefill, decode=decode, empty_cache=empty_cache,
        batch_shapes=lambda mode, b, s: _tok_shapes(cfg, mode, b, s))


def _ssm_bundle(cfg: ModelConfig) -> ModelBundle:
    def empty_cache(batch, cache_len, dtype):
        return ssm.ssm_empty_cache(cfg, batch, dtype)

    return ModelBundle(
        cfg=cfg,
        init=lambda key: ssm.init_ssm_lm(key, cfg),
        loss=lambda params, batch, **kw: ssm.ssm_loss(params, cfg, batch,
                                                      **kw),
        prefill=lambda params, batch, cache_len=None, window=None, **kw:
            ssm.ssm_prefill(params, cfg, batch["tokens"], cache_len),
        decode=lambda params, cache, tokens, lengths, window=None, **kw:
            ssm.ssm_decode(params, cfg, cache, tokens, lengths),
        empty_cache=empty_cache,
        batch_shapes=lambda mode, b, s: _tok_shapes(cfg, mode, b, s))


def _hybrid_bundle(cfg: ModelConfig) -> ModelBundle:
    def empty_cache(batch, cache_len, dtype):
        return hybrid.hybrid_empty_cache(cfg, batch, cache_len, dtype)

    return ModelBundle(
        cfg=cfg,
        init=lambda key: hybrid.init_hybrid_lm(key, cfg),
        loss=lambda params, batch, **kw: hybrid.hybrid_loss(
            params, cfg, batch, **kw),
        prefill=lambda params, batch, cache_len=None, window=None, **kw:
            hybrid.hybrid_prefill(params, cfg, batch["tokens"], cache_len,
                                  window=window),
        decode=lambda params, cache, tokens, lengths, window=None, **kw:
            hybrid.hybrid_decode(params, cfg, cache, tokens, lengths,
                                 window=window),
        empty_cache=empty_cache,
        batch_shapes=lambda mode, b, s: _tok_shapes(cfg, mode, b, s))


def _vlm_bundle(cfg: ModelConfig) -> ModelBundle:
    p, dv = cfg.n_vision_tokens, cfg.d_vision

    def batch_shapes(mode, b, s):
        base = _tok_shapes(cfg, mode, b, max(s - p, 1))
        if mode in ("train", "prefill"):
            base["vision"] = SDS((b, p, dv), cfg.jnp_dtype())
        return base

    def empty_cache(batch, cache_len, dtype):
        L = cfg.n_layers
        return {"k": jnp.zeros((L, batch, cfg.n_kv_heads, cache_len,
                                cfg.dh), dtype),
                "v": jnp.zeros((L, batch, cfg.n_kv_heads, cache_len,
                                cfg.dh), dtype)}

    return ModelBundle(
        cfg=cfg,
        init=lambda key: vlm.init_vlm(key, cfg),
        loss=lambda params, batch, **kw: vlm.vlm_loss(params, cfg, batch,
                                                      **kw),
        prefill=lambda params, batch, cache_len=None, window=None, **kw:
            vlm.vlm_prefill(params, cfg, batch, cache_len, window=window),
        decode=lambda params, cache, tokens, lengths, window=None, **kw:
            vlm.vlm_decode(params, cfg, cache, tokens, lengths),
        empty_cache=empty_cache, batch_shapes=batch_shapes)


def _audio_bundle(cfg: ModelConfig) -> ModelBundle:
    t = cfg.n_audio_ctx

    def batch_shapes(mode, b, s):
        base = _tok_shapes(cfg, mode, b, s)
        if mode in ("train", "prefill"):
            base["frames"] = SDS((b, t, cfg.d_model), cfg.jnp_dtype())
        return base

    def empty_cache(batch, cache_len, dtype):
        L, kh, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
        return {"k": jnp.zeros((L, batch, kh, cache_len, dh), dtype),
                "v": jnp.zeros((L, batch, kh, cache_len, dh), dtype),
                "cross_k": jnp.zeros((L, batch, kh, t, dh), dtype),
                "cross_v": jnp.zeros((L, batch, kh, t, dh), dtype)}

    return ModelBundle(
        cfg=cfg,
        init=lambda key: encdec.init_encdec(key, cfg),
        loss=lambda params, batch, **kw: encdec.encdec_loss(
            params, cfg, batch, **kw),
        prefill=lambda params, batch, cache_len=None, window=None, **kw:
            encdec.encdec_prefill(params, cfg, batch, cache_len,
                                  window=window),
        decode=lambda params, cache, tokens, lengths, window=None, **kw:
            encdec.encdec_decode(params, cfg, cache, tokens, lengths),
        empty_cache=empty_cache, batch_shapes=batch_shapes)


_BUILDERS = {
    "dense": _dense_bundle,
    "moe": _dense_bundle,       # MoE shares the lm.py code path
    "ssm": _ssm_bundle,
    "hybrid": _hybrid_bundle,
    "vlm": _vlm_bundle,
    "audio": _audio_bundle,
}


def get_model(cfg: ModelConfig) -> ModelBundle:
    return _BUILDERS[cfg.family](cfg)
