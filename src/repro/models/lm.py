"""Decoder-only LM family (dense GQA + MoE) — the pod-path model.

Covers phi4-mini, phi3-mini, qwen3-32b, yi-6b (dense) and
deepseek-moe-16b, qwen3-moe-30b-a3b (MoE).  PaliGemma reuses these blocks
through models/vlm.py (prefix-LM masking), Whisper through
models/encdec.py (cross-attention), Zamba2 through models/hybrid.py
(shared attention block).

Design notes (TPU-native, see DESIGN.md §6):
  * layers are **scan-stacked**: every per-layer parameter carries a
    leading ``L`` dim and the forward pass is one ``lax.scan`` — keeps
    HLO size O(1) in depth so 64-layer dry-runs lower fast.
  * attention is **query-chunked** (flash-attention structure in pure
    jnp): causal logits are never materialized beyond
    (B, H, chunk, S) — prefill_32k and train_4k stay within VMEM-scale
    transients instead of the O(S²) mask path.
  * GQA uses grouped einsums (no ``jnp.repeat`` of K/V to H heads).
  * MoE uses per-group capacity dispatch (Switch-style): tokens are
    grouped by data shard, top-k routed, gathered to (G, E, C, D) and
    expert-matmul'd with experts sharded on the ``model`` axis — the
    (data → model) reshard of the dispatch tensor is the all-to-all.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import (gather_expert_weights,
                                            shard_act, shard_expert,
                                            shard_group, shard_heads,
                                            shard_kv, shard_logits,
                                            shard_seq)

from .common import (ModelConfig, apply_rope, cross_entropy_loss,
                     dense_init, rms_norm, rope_cos_sin, split_keys)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# vocab padding (model-axis shardability: pad to a multiple of 2048 =
# 16 shards x 128 lanes)
# ---------------------------------------------------------------------------

VOCAB_PAD = 2048


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ModelConfig, dtype, n_layers: int):
    """Stacked attention params: leading dim = n_layers."""
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    ks = split_keys(key, 4)
    L = n_layers
    p = {
        "wq": dense_init(ks[0], (L, d, h, dh), dtype=dtype),
        "wk": dense_init(ks[1], (L, d, kh, dh), dtype=dtype),
        "wv": dense_init(ks[2], (L, d, kh, dh), dtype=dtype),
        "wo": dense_init(ks[3], (L, h, dh, d),
                         scale=1.0 / math.sqrt(h * dh), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((L, dh), dtype)
        p["k_norm"] = jnp.ones((L, dh), dtype)
    return p


GATED_ACTS = ("silu", "geglu")


def _gate(act: str, g):
    return jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)


def _init_mlp(key, d: int, f: int, act: str, dtype, lead=()):
    ks = split_keys(key, 3)
    p = {"wi": dense_init(ks[0], (*lead, d, f), dtype=dtype),
         "wo": dense_init(ks[1], (*lead, f, d),
                          scale=1.0 / math.sqrt(f), dtype=dtype)}
    if act in GATED_ACTS:
        p["wg"] = dense_init(ks[2], (*lead, d, f), dtype=dtype)
    return p


def _init_moe(key, cfg: ModelConfig, dtype, n_layers: int):
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = split_keys(key, 3)
    L = n_layers
    p = {
        "router": dense_init(ks[0], (L, d, e), scale=0.02, dtype=jnp.float32),
        "experts": _init_mlp(ks[1], d, fe, cfg.act, dtype, lead=(L, e)),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        p["shared"] = _init_mlp(ks[2], d, fs, cfg.act, dtype, lead=(L,))
    return p


def init_lm(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype()
    vp = padded_vocab(cfg)
    d = cfg.d_model
    ks = split_keys(key, 8)
    n_moe = cfg.n_layers - (1 if cfg.first_layer_dense_ff else 0)
    params: Params = {
        "embed": dense_init(ks[0], (vp, d), scale=0.02, dtype=dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    L = n_moe if cfg.n_experts else cfg.n_layers
    blocks = {
        "ln1": jnp.ones((L, d), dtype),
        "ln2": jnp.ones((L, d), dtype),
        "attn": _init_attn_block(ks[1], cfg, dtype, L),
    }
    if cfg.n_experts:
        blocks["moe"] = _init_moe(ks[2], cfg, dtype, L)
    else:
        blocks["mlp"] = _init_mlp(ks[2], d, cfg.d_ff, cfg.act, dtype,
                                  lead=(L,))
    params["blocks"] = blocks
    if cfg.first_layer_dense_ff:
        params["first_block"] = {
            "ln1": jnp.ones((1, d), dtype),
            "ln2": jnp.ones((1, d), dtype),
            "attn": _init_attn_block(ks[3], cfg, dtype, 1),
            "mlp": _init_mlp(ks[4], d, cfg.first_layer_dense_ff, cfg.act,
                             dtype, lead=(1,)),
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[5], (d, vp), scale=0.02,
                                       dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# attention — query-chunked causal/prefix/windowed (flash structure, jnp)
# ---------------------------------------------------------------------------

def _proj_qkv(p: Params, cfg: ModelConfig, x, positions):
    """x (B,S,D) -> q (B,S,H,dh), k/v (B,S,KH,dh) with qk_norm + RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_base:
        cos, sin = rope_cos_sin(positions, cfg.dh, cfg.rope_base)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def chunked_attention(q, k, v, cfg: ModelConfig, *,
                      prefix_len: int = 0,
                      window: Optional[int] = None,
                      chunk: int = 512) -> jnp.ndarray:
    """Causal (+prefix, +sliding-window) attention, O(S·chunk) transients.

    q (B,S,H,dh); k,v (B,S,KH,dh).  Returns (B,S,H,dh).

    GQA is handled by expanding K/V to the FLAT head dim (jnp.repeat)
    instead of reshaping Q to (KH, G, dh): the flat H axis stays
    model-sharded under GSPMD (H=64 shards 16-way; the grouped (8,8)
    reshape forced a resharding — §Perf iteration q1), and the expanded
    K/V are H-sharded so their per-device footprint is the same as the
    grouped form.
    """
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0, (s, chunk)
    scale = 1.0 / math.sqrt(dh)
    kx = shard_kv(jnp.repeat(k, g, axis=2)) if g > 1 else shard_kv(k)
    vx = shard_kv(jnp.repeat(v, g, axis=2)) if g > 1 else shard_kv(v)
    q = shard_heads(q)
    kpos = jnp.arange(s)

    def body(carry, qc_and_start):
        qc, start = qc_and_start           # (B,chunk,H,dh), ()
        qpos = start + jnp.arange(chunk)
        logits = jnp.einsum("bqhd,bshd->bhqs", qc, kx,
                            preferred_element_type=jnp.float32)
        logits = logits * scale
        mask = kpos[None, :] <= qpos[:, None]
        if prefix_len:
            mask = mask | (kpos[None, :] < prefix_len)
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", w, vx)
        return carry, out

    qs = q.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(n_chunks) * chunk
    # checkpoint the chunk body: without this, autodiff saves the softmax
    # weights of EVERY chunk — the full S^2 attention matrix — as scan
    # residuals (flash-attention recomputes instead; so do we)
    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, starts))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return out


def attention_block(p: Params, cfg: ModelConfig, x, *,
                    prefix_len: int = 0,
                    window: Optional[int] = None) -> jnp.ndarray:
    b, s, _ = x.shape
    q, k, v = _proj_qkv(p, cfg, x, jnp.arange(s))
    out = chunked_attention(q, k, v, cfg, prefix_len=prefix_len,
                            window=window)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"])


# ---------------------------------------------------------------------------
# decode attention (one token, ring KV cache)
# ---------------------------------------------------------------------------

def decode_attention_block(p: Params, cfg: ModelConfig, x, cache_k, cache_v,
                           lengths, attn_impl=None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                      jnp.ndarray]:
    """x (B,1,D); cache_k/v (B,KH,C,dh); lengths (B,) = tokens already in
    context (the new token's absolute position).  Ring-buffer update.
    Returns (out (B,1,D), new_k, new_v).

    ``attn_impl`` is the vendor-kernel hook (§4.8): when provided it
    replaces only the attention math — called as
    ``attn_impl(q (B,H,dh), kc, vc, n_valid) -> (B,H,dh)`` over the
    already-updated cache; the ring update and output projection stay
    identical to the reference path."""
    b = x.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    g = h // kh
    c = cache_k.shape[2]
    q, k, v = _proj_qkv(p, cfg, x, lengths[:, None])
    slot = (lengths % c).astype(jnp.int32)
    onehot = jax.nn.one_hot(slot, c, dtype=x.dtype)          # (B,C)
    kc = cache_k * (1 - onehot)[:, None, :, None] \
        + k[:, 0].transpose(0, 1, 2)[:, :, None, :] * onehot[:, None, :, None]
    vc = cache_v * (1 - onehot)[:, None, :, None] \
        + v[:, 0][:, :, None, :] * onehot[:, None, :, None]
    n_valid = jnp.minimum(lengths + 1, c)
    if attn_impl is not None:
        out = attn_impl(q[:, 0], kc, vc, n_valid).reshape(b, 1, h, dh)
    else:
        qg = q[:, 0].reshape(b, kh, g, dh)
        scale = 1.0 / math.sqrt(dh)
        logits = jnp.einsum("bkgd,bkcd->bkgc", qg, kc,
                            preferred_element_type=jnp.float32) * scale
        pos = jnp.arange(c)[None, None, None, :]
        valid = pos < n_valid[:, None, None, None]
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgc,bkcd->bkgd", w, vc).reshape(b, 1, h, dh)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    return y, kc, vc


# ---------------------------------------------------------------------------
# decode attention — paged KV (block pool + per-slot block table)
# ---------------------------------------------------------------------------

def paged_decode_attention_block(p: Params, cfg: ModelConfig, x,
                                 pool_k, pool_v, tables, lengths,
                                 attn_impl=None
                                 ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                            jnp.ndarray]:
    """Paged twin of ``decode_attention_block``: the slot's KV rows
    live scattered across a shared physical block pool instead of one
    contiguous ring.

    x (B,1,D); pool_k/pool_v (P,KH,BS,dh) — ONE layer's physical
    blocks; tables (B,T) int32 physical block ids in logical order
    (T*BS = the slot's logical ring capacity, entry 0 = the pool's
    garbage block for unmapped tail entries); lengths (B,) absolute
    positions.  Returns (out (B,1,D), new_pool_k, new_pool_v).

    The new token's K/V land at logical ring position ``lengths % c``
    → physical ``(tables[b, pos // BS], pos % BS)`` — a scatter, which
    is value-identical to the contiguous path's one-hot multiply-add
    (an IEEE ``k*1 + cache*0`` is exactly ``k``/``cache``).  The
    reference attention gathers the table back to a contiguous
    (B,KH,c,dh) view and runs the EXACT einsum/mask/softmax sequence
    of the contiguous block, so decoded values are bit-identical;
    ``attn_impl`` (the vendor-kernel hook, §4.8) instead receives the
    pool + table and walks blocks natively:
    ``attn_impl(q (B,H,dh), pool_k, pool_v, tables, n_valid)``."""
    b = x.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    g = h // kh
    bs = pool_k.shape[2]
    t = tables.shape[1]
    c = t * bs
    q, k, v = _proj_qkv(p, cfg, x, lengths[:, None])
    pos = (lengths % c).astype(jnp.int32)
    phys = jnp.take_along_axis(tables, (pos // bs)[:, None], axis=1)[:, 0]
    off = pos % bs
    # duplicate phys ids only ever collide on the garbage block (active
    # slots own disjoint blocks), so last-write-wins is harmless there
    pool_k = pool_k.at[phys, :, off].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[phys, :, off].set(v[:, 0].astype(pool_v.dtype))
    n_valid = jnp.minimum(lengths + 1, c)
    if attn_impl is not None:
        out = attn_impl(q[:, 0], pool_k, pool_v, tables,
                        n_valid).reshape(b, 1, h, dh)
    else:
        kc = pool_k[tables].transpose(0, 2, 1, 3, 4).reshape(b, kh, c, dh)
        vc = pool_v[tables].transpose(0, 2, 1, 3, 4).reshape(b, kh, c, dh)
        qg = q[:, 0].reshape(b, kh, g, dh)
        scale = 1.0 / math.sqrt(dh)
        logits = jnp.einsum("bkgd,bkcd->bkgc", qg, kc,
                            preferred_element_type=jnp.float32) * scale
        posc = jnp.arange(c)[None, None, None, :]
        valid = posc < n_valid[:, None, None, None]
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgc,bkcd->bkgd", w, vc).reshape(b, 1, h, dh)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    return y, pool_k, pool_v


def lm_decode_paged(params: Params, cfg: ModelConfig, pool: Dict,
                    tables, tokens, lengths, *, data_shards: int = 16,
                    embed_scale: Optional[float] = None, attn_impl=None):
    """One decode step over the paged KV pool.  tokens (B,1); lengths
    (B,); tables (B,T) int32; pool {k,v}: (L,P,KH,BS,dh).  Returns
    (logits (B,V), new_pool).  The block tables and lengths are traced
    arguments — mapping/unmapping blocks (slot growth, admission,
    retirement, checkpoint restore) changes VALUES only, so this
    program is traced exactly once per engine (the compile-once
    discipline of the lane masks, applied to KV placement)."""
    x = embed_tokens(params, cfg, tokens)
    if embed_scale is not None:
        x = x * jnp.asarray(embed_scale, x.dtype)
    i0 = 0
    if "first_block" in params:
        fb = jax.tree.map(lambda a: a[0], params["first_block"])
        xin = rms_norm(x, fb["ln1"], cfg.norm_eps)
        att, kc, vc = paged_decode_attention_block(
            fb["attn"], cfg, xin, pool["k"][0], pool["v"][0],
            tables, lengths, attn_impl=attn_impl)
        h = x + att
        hin = rms_norm(h, fb["ln2"], cfg.norm_eps)
        x = h + mlp_block(fb["mlp"], cfg, hin)
        first_kv = (kc, vc)
        i0 = 1

    def body(h, layer_in):
        p_l, pk, pv = layer_in
        xin = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        att, kc, vc = paged_decode_attention_block(
            p_l["attn"], cfg, xin, pk, pv, tables, lengths,
            attn_impl=attn_impl)
        hh = h + att
        hin = rms_norm(hh, p_l["ln2"], cfg.norm_eps)
        if "moe" in p_l:
            y, _ = moe_block(p_l["moe"], cfg, hin, data_shards)
        else:
            y = mlp_block(p_l["mlp"], cfg, hin)
        return hh + y, (kc, vc)

    x, (ks_, vs_) = jax.lax.scan(body, x,
                                 (params["blocks"], pool["k"][i0:],
                                  pool["v"][i0:]))
    if i0:
        ks_ = jnp.concatenate([first_kv[0][None], ks_])
        vs_ = jnp.concatenate([first_kv[1][None], vs_])
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {"k": ks_, "v": vs_}


def lm_prefill_chunk_paged(params: Params, cfg: ModelConfig, pool: Dict,
                           table_row, tokens, start, *,
                           window: Optional[int] = None,
                           embed_scale: Optional[float] = None,
                           data_shards: int = 16) -> Dict:
    """Paged twin of ``lm_prefill_chunk``: gather ONE slot's blocks to
    a contiguous batch=1 cache, run the exact contiguous chunk math,
    and scatter the result back into the pool.

    table_row (T,) int32 is the slot's block table; ``start`` stays a
    traced scalar and the gather/scatter indices are traced values, so
    one compiled program serves every chunk of every slot whatever
    blocks it holds.  Unmapped trailing entries point at the garbage
    block: the gather reads garbage rows the chunk's causal mask never
    attends (positions beyond ``start + S``), and the scatter writes
    them back to the garbage block where nothing reads them."""
    bs = pool["k"].shape[3]
    t = table_row.shape[0]

    def gather(p):                       # (L,P,KH,BS,dh) -> (L,1,KH,C,dh)
        l, _, kh, _, dh = p.shape
        one = p[:, table_row].transpose(0, 2, 1, 3, 4)
        return one.reshape(l, kh, t * bs, dh)[:, None]

    cache1 = {"k": gather(pool["k"]), "v": gather(pool["v"])}
    cache1 = lm_prefill_chunk(params, cfg, cache1, tokens, start,
                              window=window, embed_scale=embed_scale,
                              data_shards=data_shards)

    def scatter(p, one):                 # inverse of gather
        l, _, kh, _, dh = p.shape
        src = one[:, 0].reshape(l, kh, t, bs, dh).transpose(0, 2, 1, 3, 4)
        return p.at[:, table_row].set(src.astype(p.dtype))

    return {"k": scatter(pool["k"], cache1["k"]),
            "v": scatter(pool["v"], cache1["v"])}


# ---------------------------------------------------------------------------
# FFN — dense (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_block(p: Params, cfg: ModelConfig, x) -> jnp.ndarray:
    hidden = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.act in GATED_ACTS:
        gate = jnp.einsum("bsd,df->bsf", x, p["wg"])
        hidden = _gate(cfg.act, gate) * hidden
    else:
        hidden = jax.nn.gelu(hidden)
    return jnp.einsum("bsf,fd->bsd", hidden, p["wo"])


# ---------------------------------------------------------------------------
# FFN — MoE (per-group capacity dispatch, Switch-style)
# ---------------------------------------------------------------------------

def moe_groups(n_tokens: int, data_shards: int = 16) -> int:
    """Group count for capacity dispatch: one group per data shard when
    groups stay usefully large, else a single global group."""
    if n_tokens >= 16 * 1024:
        return data_shards
    return 1


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(4, -(-c // 4) * 4)          # >=4, multiple of 4


def moe_dispatch(router_logits, cfg: ModelConfig, capacity: int,
                 n_valid=None, eff_capacity=None):
    """router_logits (G,T,E) -> (dispatch_idx (G,E*C) int32 token ids
    [T = dropped], combine (G,E*C) weights, aux_loss scalar).

    Capacity-stable masked mode (bucketed MoE prefill): when
    ``n_valid`` / ``eff_capacity`` are given (TRACED scalars), T is a
    right-PADDED token count and ``capacity`` the bucket's python-int
    capacity — the compiled shape.  Tokens at flat positions >=
    ``n_valid`` are dropped outright and real tokens keep only queue
    positions < ``eff_capacity`` (the true length's capacity), so the
    kept set — and, because right-padding appends to the END of the
    cumsum order, every kept token's queue position — is exactly what
    the unpadded dispatch at the true length computes.  One compile
    per bucket, bit-identical expert routing per true length."""
    g, t, e = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)          # (G,T,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch):  E * sum_e f_e * p_e
    density = jnp.mean(jax.nn.one_hot(top_ids[..., 0], e), axis=1)   # (G,E)
    p_mean = jnp.mean(probs, axis=1)                                  # (G,E)
    aux = jnp.mean(jnp.sum(density * p_mean, axis=-1)) * e

    flat_ids = top_ids.reshape(g, t * cfg.top_k)              # (G,TK)
    flat_w = top_w.reshape(g, t * cfg.top_k)
    # position of each (token,k) within its expert queue
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)     # (G,TK,E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                 # (G,TK,E)
    pos = jnp.take_along_axis(pos_in_e, flat_ids[..., None],
                              axis=-1)[..., 0]                # (G,TK)
    token_of = jnp.arange(t * cfg.top_k) // cfg.top_k         # (TK,)
    if n_valid is not None:
        cap_eff = capacity if eff_capacity is None else eff_capacity
        keep = (pos < cap_eff) & (token_of[None, :] < n_valid)
    else:
        keep = pos < capacity
    slot = flat_ids * capacity + pos                          # (G,TK)
    slot = jnp.where(keep, slot, e * capacity)                # overflow bin
    # scatter token ids into slots; default T = dummy token
    dispatch = jnp.full((g, e * capacity + 1), t, jnp.int32)
    combine = jnp.zeros((g, e * capacity + 1), jnp.float32)
    gi = jnp.arange(g)[:, None]
    dispatch = dispatch.at[gi, slot].set(
        jnp.broadcast_to(token_of, (g, t * cfg.top_k)).astype(jnp.int32),
        mode="drop")
    combine = combine.at[gi, slot].set(flat_w, mode="drop")
    return dispatch[:, :-1], combine[:, :-1], aux


def moe_block(p: Params, cfg: ModelConfig, x,
              data_shards: int = 16, n_valid=None,
              eff_capacity=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> (y, aux_loss).  Expert-parallel capacity dispatch.

    When an activation-sharding context is active and shapes divide,
    delegates to the shard_map all-to-all implementation (§Perf C4) —
    explicit EP collectives instead of GSPMD-inferred ones.

    ``n_valid`` / ``eff_capacity`` (TRACED scalars) switch
    ``moe_dispatch`` into its capacity-stable masked mode for
    bucketed-prefill serving: S is a right-padded bucket length and
    expert capacity a function of the BUCKET (the compiled shape)
    while the dispatch masks to the true length's capacity — see
    ``moe_dispatch``.  Masked mode keeps the single-group layout
    (token positions across groups would not survive padding)."""
    b, s, d = x.shape
    from .moe_ep import ep_applicable, moe_block_ep
    if n_valid is None and ep_applicable(cfg, b, s):
        return moe_block_ep(p, cfg, x)
    t_all = b * s
    g = moe_groups(t_all, data_shards)
    if n_valid is not None and g != 1:
        raise ValueError("capacity-stable masked dispatch requires the "
                         "single-group layout (got %d groups)" % g)
    t = t_all // g
    xg = shard_group(x.reshape(g, t, d))
    cap = moe_capacity(cfg, t)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    dispatch, combine, aux = moe_dispatch(logits, cfg, cap,
                                          n_valid=n_valid,
                                          eff_capacity=eff_capacity)
    # pad a zero token row for dropped/dummy slots
    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(xpad, dispatch[..., None], axis=1)  # (G,EC,D)
    # pin dispatch tensors expert-parallel: groups on data, experts on
    # model — the reshard from token-grouped to expert-parallel IS the
    # all-to-all; without the pins GSPMD replicates (§Perf C2)
    xe = shard_expert(xe.reshape(g, cfg.n_experts, cap, d))
    we = p["experts"]
    wi = gather_expert_weights(we["wi"])
    wo = gather_expert_weights(we["wo"])
    hid = jnp.einsum("gecd,edf->gecf", xe, wi)
    if cfg.act in GATED_ACTS:
        gate = jnp.einsum("gecd,edf->gecf", xe,
                          gather_expert_weights(we["wg"]))
        hid = _gate(cfg.act, gate) * hid
    else:
        hid = jax.nn.gelu(hid)
    hid = shard_expert(hid)
    ye = shard_expert(jnp.einsum("gecf,efd->gecd", hid, wo))
    ye = (ye.reshape(g, cfg.n_experts * cap, d)
          * combine[..., None].astype(ye.dtype))
    # combine back: scatter-add slots to tokens
    ypad = jnp.zeros((g, t + 1, d), ye.dtype)
    y = shard_group(
        ypad.at[jnp.arange(g)[:, None], dispatch].add(ye)[:, :t])
    if cfg.n_shared_experts:
        sh = p["shared"]
        hid = jnp.einsum("gtd,df->gtf", xg, sh["wi"])
        if cfg.act in GATED_ACTS:
            gate = jnp.einsum("gtd,df->gtf", xg, sh["wg"])
            hid = _gate(cfg.act, gate) * hid
        else:
            hid = jax.nn.gelu(hid)
        y = y + jnp.einsum("gtf,fd->gtd", hid, sh["wo"])
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# transformer layers (scan-stacked)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ModelConfig, x, p_l, *, prefix_len=0, window=None,
               data_shards: int = 16):
    # layer boundaries are sequence-parallel (§Perf A2): the remat
    # residual and the norm/elementwise traffic shard S over `model`;
    # GSPMD gathers before the projections and scatters after
    x = shard_seq(x)
    h = x + attention_block(p_l["attn"], cfg,
                            rms_norm(x, p_l["ln1"], cfg.norm_eps),
                            prefix_len=prefix_len, window=window)
    h = shard_seq(h)
    hin = rms_norm(h, p_l["ln2"], cfg.norm_eps)
    if "moe" in p_l:
        y, aux = moe_block(p_l["moe"], cfg, hin, data_shards)
    elif "mlp" in p_l:
        y, aux = mlp_block(p_l["mlp"], cfg, hin), 0.0
    return shard_seq(h + y), aux


def lm_backbone(params: Params, cfg: ModelConfig, x, *,
                prefix_len: int = 0, window: Optional[int] = None,
                remat: bool = False, data_shards: int = 16) -> Tuple:
    """Embedded input x (B,S,D) -> (hidden (B,S,D), aux_loss)."""
    aux_total = 0.0
    if "first_block" in params:
        fb = jax.tree.map(lambda a: a[0], params["first_block"])
        x, aux = _layer_fwd(cfg, x, fb, prefix_len=prefix_len, window=window,
                            data_shards=data_shards)
        aux_total += aux

    def body(carry, p_l):
        h, aux_acc = carry
        h, aux = _layer_fwd(cfg, h, p_l, prefix_len=prefix_len,
                            window=window, data_shards=data_shards)
        return (h, aux_acc + aux), None

    fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(aux_total)),
                               params["blocks"])
    return x, aux


def lm_logits(params: Params, cfg: ModelConfig, h) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return shard_logits(jnp.einsum("bsd,dv->bsv", h, head))


def embed_tokens(params: Params, cfg: ModelConfig, tokens) -> jnp.ndarray:
    return shard_act(jnp.take(params["embed"], tokens, axis=0))


# ---------------------------------------------------------------------------
# public steps
# ---------------------------------------------------------------------------

def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            *, remat: bool = True, data_shards: int = 16) -> Tuple:
    """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = pad)."""
    x = embed_tokens(params, cfg, batch["tokens"])
    h, aux = lm_backbone(params, cfg, x, remat=remat,
                         data_shards=data_shards)
    logits = lm_logits(params, cfg, h)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    labels = jnp.maximum(batch["labels"], 0)
    loss = cross_entropy_loss(logits, labels, mask)
    metrics = {"ce_loss": loss, "aux_loss": aux}
    if cfg.n_experts:
        loss = loss + 0.01 * aux
    return loss, metrics


def lm_prefill(params: Params, cfg: ModelConfig, tokens,
               cache_len: Optional[int] = None, *,
               window: Optional[int] = None,
               prefix_len: int = 0, data_shards: int = 16,
               prefix_embed: Optional[jnp.ndarray] = None,
               embed_scale: Optional[float] = None,
               n_valid=None, moe_cap=None):
    """tokens (B,S) -> (last-token logits (B,V), cache dict).

    cache layout: k/v (L, B, KH, C, dh) ring-indexed by absolute pos.
    ``prefix_embed`` (B,P,D) prepends already-embedded tokens (VLM
    vision prefix); combined with ``prefix_len`` for prefix-LM masking.
    ``n_valid`` / ``moe_cap`` (TRACED scalars) are the capacity-stable
    bucketed-MoE mode: S is a right-padded bucket length, ``n_valid``
    the true token count and ``moe_cap`` the true length's expert
    capacity — threaded into every ``moe_block`` so expert capacity is
    a function of the bucket shape, not the true length (one compile
    per bucket; see ``moe_dispatch``).
    """
    x = embed_tokens(params, cfg, tokens)
    if embed_scale is not None:
        x = x * jnp.asarray(embed_scale, x.dtype)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    b, s = x.shape[0], x.shape[1]
    c = cache_len or s
    # run backbone while capturing per-layer K/V
    kvs = []

    def layer_with_kv(x, p_l):
        xin = rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(p_l["attn"], cfg, xin, jnp.arange(s))
        out = chunked_attention(q, k, v, cfg, prefix_len=prefix_len,
                                window=window)
        h = x + jnp.einsum("bqhk,hkd->bqd", out, p_l["attn"]["wo"])
        hin = rms_norm(h, p_l["ln2"], cfg.norm_eps)
        if "moe" in p_l:
            y, _ = moe_block(p_l["moe"], cfg, hin, data_shards,
                             n_valid=n_valid, eff_capacity=moe_cap)
        else:
            y = mlp_block(p_l["mlp"], cfg, hin)
        return h + y, (k, v)

    def scan_body(h, p_l):
        h, kv = layer_with_kv(h, p_l)
        return h, kv

    if "first_block" in params:
        fb = jax.tree.map(lambda a: a[0], params["first_block"])
        x, kv0 = layer_with_kv(x, fb)
        kvs.append(kv0)
    x, (ks_, vs_) = jax.lax.scan(scan_body, x, params["blocks"])
    logits = lm_logits(params, cfg, x[:, -1:])[:, 0]

    def to_cache(k):                       # (B,S,KH,dh) -> (B,KH,C,dh)
        kc = jnp.zeros((b, cfg.n_kv_heads, c, cfg.dh), k.dtype)
        take = min(s, c)
        src = k[:, s - take:].transpose(0, 2, 1, 3)
        if c >= s:
            return jax.lax.dynamic_update_slice(kc, src, (0, 0, 0, 0))
        # ring: last c tokens land at slots (pos % c)
        pos = (jnp.arange(s - take, s) % c)
        return kc.at[:, :, pos].set(src)

    if kvs:
        k0, v0 = kvs[0]
        ks_ = jnp.concatenate([to_cache(k0)[None], jax.vmap(to_cache)(ks_)])
        vs_ = jnp.concatenate([to_cache(v0)[None], jax.vmap(to_cache)(vs_)])
    else:
        ks_ = jax.vmap(to_cache)(ks_)
        vs_ = jax.vmap(to_cache)(vs_)
    return logits, {"k": ks_, "v": vs_}


def lm_prefill_chunk(params: Params, cfg: ModelConfig, cache: Dict,
                     tokens, start, *, window: Optional[int] = None,
                     embed_scale: Optional[float] = None,
                     data_shards: int = 16) -> Dict:
    """One prompt CHUNK through the backbone: tokens (B,S) occupy
    absolute positions ``start .. start+S`` of a cache that already
    holds every earlier position.  Returns the updated cache only —
    the engine hands the last prompt token to the decode loop, so
    chunk steps never pay for logits.

    ``start`` is a TRACED scalar: one compiled program serves every
    chunk of every prompt (the chunked-prefill analogue of the masked
    pool's traced active mask).  The attention body mirrors
    ``chunked_attention``'s einsum/mask/softmax structure exactly —
    cache positions beyond the causal horizon are masked to -1e30,
    i.e. exactly-zero softmax weight — which is what keeps chunked
    prefill token-identical to one-shot prefill for families whose
    decode is length-masked (dense/vlm; see docs/PREEMPTION.md §4).
    Requires ``start + S <= cache_len`` (no ring wrap): the serving
    engine falls back to one-shot exact prefill past that."""
    x = embed_tokens(params, cfg, tokens)
    if embed_scale is not None:
        x = x * jnp.asarray(embed_scale, x.dtype)
    s = x.shape[1]
    g = cfg.n_heads // cfg.n_kv_heads
    positions = start + jnp.arange(s)
    scale = 1.0 / math.sqrt(cfg.dh)

    def attend(p_attn, xin, ck, cv):
        # ck/cv (B,KH,C,dh): write the chunk's K/V at its absolute
        # positions, then attend the chunk's queries over the cache
        c = ck.shape[2]
        q, k, v = _proj_qkv(p_attn, cfg, xin, positions)
        ck = jax.lax.dynamic_update_slice(
            ck, k.transpose(0, 2, 1, 3).astype(ck.dtype),
            (0, 0, start, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, v.transpose(0, 2, 1, 3).astype(cv.dtype),
            (0, 0, start, 0))
        ks = ck.transpose(0, 2, 1, 3)          # (B,C,KH,dh)
        vs = cv.transpose(0, 2, 1, 3)
        kx = shard_kv(jnp.repeat(ks, g, axis=2)) if g > 1 else shard_kv(ks)
        vx = shard_kv(jnp.repeat(vs, g, axis=2)) if g > 1 else shard_kv(vs)
        qx = shard_heads(q)
        kpos = jnp.arange(c)
        logits = jnp.einsum("bqhd,bshd->bhqs", qx, kx,
                            preferred_element_type=jnp.float32)
        logits = logits * scale
        mask = kpos[None, :] <= positions[:, None]
        if window is not None:
            mask = mask & (kpos[None, :] > positions[:, None] - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(vx.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", w, vx)
        y = jnp.einsum("bqhk,hkd->bqd", out, p_attn["wo"])
        return y, ck, cv

    def layer(h, p_l, ck, cv):
        xin = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        att, ck, cv = attend(p_l["attn"], xin, ck, cv)
        hh = h + att
        hin = rms_norm(hh, p_l["ln2"], cfg.norm_eps)
        if "moe" in p_l:
            y, _ = moe_block(p_l["moe"], cfg, hin, data_shards)
        else:
            y = mlp_block(p_l["mlp"], cfg, hin)
        return hh + y, ck, cv

    i0 = 0
    if "first_block" in params:
        fb = jax.tree.map(lambda a: a[0], params["first_block"])
        x, k0, v0 = layer(x, fb, cache["k"][0], cache["v"][0])
        first_kv = (k0, v0)
        i0 = 1

    def body(h, layer_in):
        p_l, ck, cv = layer_in
        h, kc, vc = layer(h, p_l, ck, cv)
        return h, (kc, vc)

    x, (ks_, vs_) = jax.lax.scan(body, x,
                                 (params["blocks"], cache["k"][i0:],
                                  cache["v"][i0:]))
    if i0:
        ks_ = jnp.concatenate([first_kv[0][None], ks_])
        vs_ = jnp.concatenate([first_kv[1][None], vs_])
    return {"k": ks_, "v": vs_}


def lm_decode(params: Params, cfg: ModelConfig, cache: Dict, tokens,
              lengths, *, data_shards: int = 16,
              embed_scale: Optional[float] = None, attn_impl=None):
    """One decode step.  tokens (B,1); lengths (B,) absolute positions;
    cache {k,v}: (L,B,KH,C,dh).  Returns (logits (B,V), new_cache).
    ``attn_impl`` plumbs a vendor attention kernel into every layer's
    decode_attention_block (§4.8)."""
    x = embed_tokens(params, cfg, tokens)
    if embed_scale is not None:
        x = x * jnp.asarray(embed_scale, x.dtype)
    i0 = 0
    if "first_block" in params:
        fb = jax.tree.map(lambda a: a[0], params["first_block"])
        xin = rms_norm(x, fb["ln1"], cfg.norm_eps)
        att, kc, vc = decode_attention_block(fb["attn"], cfg, xin,
                                             cache["k"][0], cache["v"][0],
                                             lengths, attn_impl=attn_impl)
        h = x + att
        hin = rms_norm(h, fb["ln2"], cfg.norm_eps)
        x = h + mlp_block(fb["mlp"], cfg, hin)
        first_kv = (kc, vc)
        i0 = 1

    def body(h, layer_in):
        p_l, ck, cv = layer_in
        xin = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        att, kc, vc = decode_attention_block(p_l["attn"], cfg, xin, ck, cv,
                                             lengths, attn_impl=attn_impl)
        hh = h + att
        hin = rms_norm(hh, p_l["ln2"], cfg.norm_eps)
        if "moe" in p_l:
            y, _ = moe_block(p_l["moe"], cfg, hin, data_shards)
        else:
            y = mlp_block(p_l["mlp"], cfg, hin)
        return hh + y, (kc, vc)

    x, (ks_, vs_) = jax.lax.scan(body, x,
                                 (params["blocks"], cache["k"][i0:],
                                  cache["v"][i0:]))
    if i0:
        ks_ = jnp.concatenate([first_kv[0][None], ks_])
        vs_ = jnp.concatenate([first_kv[1][None], vs_])
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {"k": ks_, "v": vs_}
