"""Mamba-2 (SSD — state-space duality) family [arXiv:2405.21060].

Pure-jnp chunked SSD for the pod path (GSPMD-shardable: heads on the
``model`` axis, batch on ``data``; the chunk scan carries state through
``lax.scan`` — no cross-chip collectives inside the scan, sequence stays
on-chip).  ``repro.kernels.ssd_scan`` is the Pallas TPU kernel for the
same math (selected via the vendor-tag mechanism on the micro path).

Decode is O(1) per token: the "KV cache" is the (B,G,gh,P,N) SSD state
plus the (K-1)-deep causal-conv ring — this is why mamba2/zamba2 run
long_500k natively.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import shard_act

from .common import (ModelConfig, cross_entropy_loss, dense_init, rms_norm,
                     split_keys)
from .lm import embed_tokens, lm_logits, padded_vocab

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_ssm_block(key, cfg: ModelConfig, dtype, n_layers: int) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, k = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    conv_ch = di + 2 * g * n
    d_in_proj = 2 * di + 2 * g * n + h
    ks = split_keys(key, 4)
    L = n_layers
    import numpy as np
    rng = np.random.default_rng(7)
    dt = np.exp(rng.uniform(math.log(1e-3), math.log(1e-1), (L, h)))
    dt_bias = dt + np.log(-np.expm1(-dt))          # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (L, d, d_in_proj), dtype=dtype),
        "conv_w": dense_init(ks[1], (L, k, conv_ch), scale=0.5,
                             dtype=dtype),
        "conv_b": jnp.zeros((L, conv_ch), dtype),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, h + 1, dtype=jnp.float32),
                                  (L, 1)) / h + 0.5),
        "D": jnp.ones((L, h), jnp.float32),
        "norm": jnp.ones((L, di), dtype),
        "out_proj": dense_init(ks[2], (L, di, d),
                               scale=1.0 / math.sqrt(di), dtype=dtype),
        "ln": jnp.ones((L, d), dtype),
    }


def init_ssm_lm(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype()
    vp = padded_vocab(cfg)
    ks = split_keys(key, 3)
    params: Params = {
        "embed": dense_init(ks[0], (vp, cfg.d_model), scale=0.02,
                            dtype=dtype),
        "blocks": init_ssm_block(ks[1], cfg, dtype, cfg.n_layers),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, vp),
                                       scale=0.02, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# SSD chunked scan (pure jnp; heads grouped for B/C sharing)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int = 128,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative;
    Bm/Cm (B,S,G,N).  Returns (y (B,S,H,P), final_state (B,G,gh,P,N))."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2:]
    gh = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xg = x.reshape(b, nc, chunk, g, gh, p)
    dtg = dt.reshape(b, nc, chunk, g, gh)
    Bc = Bm.reshape(b, nc, chunk, g, n)
    Cc = Cm.reshape(b, nc, chunk, g, n)
    Ag = A.reshape(g, gh)
    if init_state is None:
        init_state = jnp.zeros((b, g, gh, p, n), jnp.float32)

    def body(state, inp):
        xc, dtc, bc, cc = inp              # (B,Q,G,gh,P) (B,Q,G,gh) ...
        dA = dtc * Ag                      # (B,Q,G,gh) log-decay, <0
        La = jnp.cumsum(dA, axis=1)        # cumulative within chunk
        # --- intra-chunk (masked attention-like) ---
        cb = jnp.einsum("bign,bjgn->bgij", cc, bc,
                        preferred_element_type=jnp.float32)
        ldiff = La[:, :, None] - La[:, None]          # (B,i,j,G,gh)
        q_ = jnp.arange(chunk)
        causal = (q_[:, None] >= q_[None, :])
        # mask in log space BEFORE exp: ldiff > 0 for j > i would overflow
        # (and poison gradients through the masked branch)
        ldiff = jnp.where(causal[None, :, :, None, None], ldiff, -1e30)
        m = jnp.exp(ldiff)
        m = m * dtc[:, None]                          # * dt_j
        m = m * cb.transpose(0, 2, 3, 1)[..., None]   # (B,i,j,G,gh)
        y_intra = jnp.einsum("bijgh,bjghp->bighp", m,
                             xc.astype(jnp.float32))
        # --- inter-chunk (state from previous chunks) ---
        y_inter = jnp.einsum("bign,bghpn->bighp", cc.astype(jnp.float32),
                             state) * jnp.exp(La)[..., None]
        # --- state update ---
        la_end = La[:, -1]                            # (B,G,gh)
        decay_to_end = jnp.exp(la_end[:, None] - La) * dtc  # (B,Q,G,gh)
        ds = jnp.einsum("bjgn,bjgh,bjghp->bghpn", bc.astype(jnp.float32),
                        decay_to_end, xc.astype(jnp.float32))
        state = state * jnp.exp(la_end)[..., None, None] + ds
        return state, (y_intra + y_inter).astype(x.dtype)

    xs = (xg.transpose(1, 0, 2, 3, 4, 5), dtg.transpose(1, 0, 2, 3, 4),
          Bc.transpose(1, 0, 2, 3, 4), Cc.transpose(1, 0, 2, 3, 4))
    # checkpoint: avoid saving the (Q,Q) intra-chunk matrices per chunk
    state, ys = jax.lax.scan(jax.checkpoint(body), init_state, xs)
    y = ys.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, p)
    return y, state


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token recurrence.  state (B,G,gh,P,N); x_t (B,H,P);
    dt_t (B,H); B_t/C_t (B,G,N).  Returns (y_t (B,H,P), new_state)."""
    b, h, p = x_t.shape
    g, n = B_t.shape[1:]
    gh = h // g
    xg = x_t.reshape(b, g, gh, p).astype(jnp.float32)
    dtg = dt_t.reshape(b, g, gh)
    Ag = A.reshape(g, gh)
    dA = jnp.exp(dtg * Ag)                            # (B,G,gh)
    ds = jnp.einsum("bgn,bgh,bghp->bghpn", B_t.astype(jnp.float32),
                    dtg, xg)
    state = state * dA[..., None, None] + ds
    y = jnp.einsum("bgn,bghpn->bghp", C_t.astype(jnp.float32), state)
    return y.reshape(b, h, p).astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# mamba2 block (conv + SSD + gated norm)
# ---------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, zxbcdt):
    di, g, n, h = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                   cfg.ssm_heads)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """xBC (B,S,C) depthwise causal conv, kernel (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i][None, None]
              for i in range(k))
    return jax.nn.silu(out + b[None, None])


def _conv_step(conv_cache, x_t, w, b):
    """conv_cache (B,K-1,C); x_t (B,C).  Returns (y_t, new_cache)."""
    k = w.shape[0]
    full = jnp.concatenate([conv_cache, x_t[:, None]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", full, w) + b[None]
    return jax.nn.silu(y), full[:, 1:]


def mamba_block(p_l: Params, cfg: ModelConfig, x, *,
                chunk: int = 128) -> jnp.ndarray:
    """Full-sequence mamba2 block: x (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    x = shard_act(x)
    xin = rms_norm(x, p_l["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p_l["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p_l["conv_w"], p_l["conv_b"])
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    h, ph = cfg.ssm_heads, cfg.ssm_head_dim
    xs = xBC[..., :di].reshape(b, s, h, ph)
    Bm = xBC[..., di:di + g * n].reshape(b, s, g, n)
    Cm = xBC[..., di + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p_l["dt_bias"])
    A = -jnp.exp(p_l["A_log"])
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, chunk=chunk)
    y = y + xs * p_l["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p_l["norm"], cfg.norm_eps)
    return x + jnp.einsum("bse,ed->bsd", y, p_l["out_proj"])


def mamba_decode_block(p_l: Params, cfg: ModelConfig, x, conv_cache,
                       state) -> Tuple[jnp.ndarray, jnp.ndarray,
                                       jnp.ndarray]:
    """One-token mamba2 block.  x (B,1,D).  Returns (y, conv, state)."""
    b = x.shape[0]
    xin = rms_norm(x, p_l["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p_l["in_proj"])[:, 0]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC, conv_cache = _conv_step(conv_cache, xBC, p_l["conv_w"],
                                 p_l["conv_b"])
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    h, ph = cfg.ssm_heads, cfg.ssm_head_dim
    xs = xBC[..., :di].reshape(b, h, ph)
    Bm = xBC[..., di:di + g * n].reshape(b, g, n)
    Cm = xBC[..., di + g * n:].reshape(b, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p_l["dt_bias"])
    A = -jnp.exp(p_l["A_log"])
    y, state = ssd_step(state, xs, dt, A, Bm, Cm)
    y = y + xs * p_l["D"][None, :, None].astype(y.dtype)
    y = y.reshape(b, di)
    y = rms_norm(y * jax.nn.silu(z), p_l["norm"], cfg.norm_eps)
    out = x + jnp.einsum("be,ed->bd", y, p_l["out_proj"])[:, None]
    return out, conv_cache, state


# ---------------------------------------------------------------------------
# public steps (pure-SSM LM: mamba2-780m)
# ---------------------------------------------------------------------------

def ssm_backbone(params, cfg: ModelConfig, x, *, remat: bool = False,
                 chunk: int = 128):
    def body(h, p_l):
        return mamba_block(p_l, cfg, h, chunk=chunk), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["blocks"])
    return x


def ssm_loss(params, cfg: ModelConfig, batch, *, remat: bool = True,
             data_shards: int = 16):
    x = embed_tokens(params, cfg, batch["tokens"])
    h = ssm_backbone(params, cfg, x, remat=remat)
    logits = lm_logits(params, cfg, h)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    labels = jnp.maximum(batch["labels"], 0)
    loss = cross_entropy_loss(logits, labels, mask)
    return loss, {"ce_loss": loss}


def ssm_empty_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    g, n = cfg.ssm_groups, cfg.ssm_state
    gh, ph = cfg.ssm_heads // g, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * g * n
    L = cfg.n_layers
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((L, batch, g, gh, ph, n), jnp.float32),
    }


def ssm_prefill(params, cfg: ModelConfig, tokens,
                cache_len: Optional[int] = None, **_):
    """Prefill = full forward capturing final conv window + SSD state."""
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    k = cfg.ssm_conv

    def body(h, p_l):
        bb, ss, d = h.shape
        xin = rms_norm(h, p_l["ln"], cfg.norm_eps)
        zxbcdt = jnp.einsum("bsd,de->bse", xin, p_l["in_proj"])
        z, xBC, dt = _split_proj(cfg, zxbcdt)
        conv_tail = jnp.pad(xBC, ((0, 0), (max(k - 1 - ss, 0), 0),
                                  (0, 0)))[:, -(k - 1):]
        xBC = _causal_conv(xBC, p_l["conv_w"], p_l["conv_b"])
        di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
        hh, ph = cfg.ssm_heads, cfg.ssm_head_dim
        xs = xBC[..., :di].reshape(bb, ss, hh, ph)
        Bm = xBC[..., di:di + g * n].reshape(bb, ss, g, n)
        Cm = xBC[..., di + g * n:].reshape(bb, ss, g, n)
        dtf = jax.nn.softplus(dt.astype(jnp.float32) + p_l["dt_bias"])
        A = -jnp.exp(p_l["A_log"])
        y, state = ssd_chunked(xs, dtf, A, Bm, Cm)
        y = y + xs * p_l["D"][None, None, :, None].astype(y.dtype)
        y = y.reshape(bb, ss, di)
        y = rms_norm(y * jax.nn.silu(z), p_l["norm"], cfg.norm_eps)
        return h + jnp.einsum("bse,ed->bsd", y, p_l["out_proj"]), \
            (conv_tail, state)

    x, (convs, states) = jax.lax.scan(body, x, params["blocks"])
    logits = lm_logits(params, cfg, x[:, -1:])[:, 0]
    return logits, {"conv": convs, "state": states}


def mamba_chunk_block(p_l: Params, cfg: ModelConfig, h, conv, state,
                      n_real) -> Tuple[jnp.ndarray, jnp.ndarray,
                                       jnp.ndarray]:
    """One mamba2 layer over a right-padded chunk with CARRIED state.

    ``conv`` (B,K-1,C) is the pre-activation conv window after the
    tokens integrated so far; ``state`` (B,G,gh,P,N) the SSD state;
    ``n_real`` a TRACED scalar — the number of real tokens in this
    chunk (the rest is right-padding).  Padded positions are exact
    state no-ops: their dt is masked to 0.0, so inside ``ssd_chunked``
    the decay ``exp(dt*A)`` is exactly 1 and the input contribution
    ``B*dt*x`` exactly 0, and the carried conv window is sliced to
    end at the last REAL token.  Returns ``(h_out, conv, state)``
    advanced by exactly ``n_real`` tokens.
    """
    bb, ss, _ = h.shape
    k = cfg.ssm_conv
    pos = jnp.arange(ss)
    xin = rms_norm(h, p_l["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p_l["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # depthwise causal conv continued from the carried window: the
    # pre-activation window replaces _causal_conv's zero left-pad
    full = jnp.concatenate([conv, xBC], axis=1)           # (B,K-1+S,C)
    new_conv = jax.lax.dynamic_slice(
        full, (0, n_real, 0), (bb, k - 1, full.shape[2]))
    out = sum(full[:, i:i + ss] * p_l["conv_w"][i][None, None]
              for i in range(k))
    xBC = jax.nn.silu(out + p_l["conv_b"][None, None])
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    hh, ph = cfg.ssm_heads, cfg.ssm_head_dim
    xs = xBC[..., :di].reshape(bb, ss, hh, ph)
    Bm = xBC[..., di:di + g * n].reshape(bb, ss, g, n)
    Cm = xBC[..., di + g * n:].reshape(bb, ss, g, n)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p_l["dt_bias"])
    dtf = jnp.where(pos[None, :, None] < n_real, dtf, 0.0)
    A = -jnp.exp(p_l["A_log"])
    y, state = ssd_chunked(xs, dtf, A, Bm, Cm, init_state=state)
    y = y + xs * p_l["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bb, ss, di)
    y = rms_norm(y * jax.nn.silu(z), p_l["norm"], cfg.norm_eps)
    h_out = h + jnp.einsum("bse,ed->bsd", y, p_l["out_proj"])
    return h_out, new_conv, state


def ssm_prefill_chunk(params, cfg: ModelConfig, cache, tokens, n_real,
                      **_):
    """Advance a batch=1 recurrent cache by one right-padded chunk of
    prompt tokens (the SERVING_PREFILL_CHUNK_STATE body).

    A chunk boundary is just a state checkpoint: the carried
    (conv, state) cache is a traced argument and ``n_real`` (the true
    chunk length) a traced scalar, so ONE compiled program serves
    every chunk of every prompt — start offsets do not exist for a
    recurrent model.  See ``mamba_chunk_block`` for the exactness
    argument on the padded tail.
    """
    x = embed_tokens(params, cfg, tokens)

    def body(h, layer_in):
        p_l, conv, state = layer_in
        h, conv, state = mamba_chunk_block(p_l, cfg, h, conv, state,
                                           n_real)
        return h, (conv, state)

    _, (convs, states) = jax.lax.scan(
        body, x, (params["blocks"], cache["conv"], cache["state"]))
    return {"conv": convs, "state": states}


def ssm_decode(params, cfg: ModelConfig, cache, tokens, lengths, **_):
    x = embed_tokens(params, cfg, tokens)

    def body(h, layer_in):
        p_l, conv, state = layer_in
        h, conv, state = mamba_decode_block(p_l, cfg, h, conv, state)
        return h, (conv, state)

    x, (convs, states) = jax.lax.scan(
        body, x, (params["blocks"], cache["conv"], cache["state"]))
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {"conv": convs, "state": states}
