"""GQA attention (RoPE, qk_norm, sliding window, prefix-LM) — reference
jnp implementation.

This is the GSPMD-friendly path used by pjit lowering (the partitioner
freely shards heads / head_dim / sequence).  The Pallas flash/decode
kernels in repro.kernels are the TPU-optimized equivalents, selected via
the same vendor-tag mechanism the micro path uses; models take a
``backend`` flag ("reference" | "pallas").
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_init, rms_norm, \
    rope_cos_sin, split_keys

Params = Dict[str, jnp.ndarray]


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), dtype=dtype),
        "wk": dense_init(ks[1], (d, kh, dh), dtype=dtype),
        "wv": dense_init(ks[2], (d, kh, dh), dtype=dtype),
        "wo": dense_init(ks[3], (h, dh, d), scale=1.0 / math.sqrt(h * dh),
                         dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray):
    """x (B,S,D) -> q (B,S,H,dh), k/v (B,S,KH,dh), RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_base:
        cos, sin = rope_cos_sin(positions, cfg.dh, cfg.rope_base)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attention_prefill(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                      *, prefix_len: int = 0,
                      window: Optional[int] = None,
                      cross_kv: Optional[Tuple] = None,
                      backend: str = "reference") -> jnp.ndarray:
    """Full-sequence attention.  prefix_len>0 gives PaliGemma prefix-LM
    masking (bidirectional over the first prefix_len positions).
    cross_kv=(k,v) switches to cross-attention (no causal mask, no rope
    on loaded kv)."""
    b, s, d = x.shape
    group = cfg.n_heads // cfg.n_kv_heads
    positions = jnp.arange(s)
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k, v = cross_kv
        mask = None
    else:
        q, k, v = _project_qkv(p, cfg, x, positions)
        qi = positions[:, None]
        kj = positions[None, :]
        mask = kj <= qi
        if prefix_len:
            mask = mask | (kj < prefix_len)
        if window is not None:
            mask = mask & (kj > qi - window)
    if backend == "pallas" and cross_kv is None:
        from repro.kernels import flash_attention

        assert not prefix_len, "pallas prefill path is pure-causal"
        out = flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True, window=window)
        out = out.transpose(0, 2, 1, 3)
    else:
        kx = jnp.repeat(k, group, axis=2) if group > 1 else k
        vx = jnp.repeat(v, group, axis=2) if group > 1 else v
        scale = 1.0 / math.sqrt(cfg.dh)
        logits = jnp.einsum("bqhk,bshk->bhqs", q, kx).astype(jnp.float32)
        logits = logits * scale
        if mask is not None:
            logits = jnp.where(mask[None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshk->bqhk", w, vx)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"])


def prefill_kv(p: Params, cfg: ModelConfig, x: jnp.ndarray,
               cache_len: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute K/V for the whole prompt and place them in a fresh cache
    of length cache_len (the serving engine pads/rings)."""
    b, s, _ = x.shape
    _, k, v = _project_qkv(p, cfg, x, jnp.arange(s))
    kc = jnp.zeros((b, cfg.n_kv_heads, cache_len, cfg.dh), x.dtype)
    vc = jnp.zeros((b, cfg.n_kv_heads, cache_len, cfg.dh), x.dtype)
    take = min(s, cache_len)
    kc = jax.lax.dynamic_update_slice(
        kc, k[:, s - take:].transpose(0, 2, 1, 3), (0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(
        vc, v[:, s - take:].transpose(0, 2, 1, 3), (0, 0, 0, 0))
    return kc, vc


def attention_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                     cache: Dict[str, jnp.ndarray],
                     lengths: jnp.ndarray,
                     *, window: Optional[int] = None,
                     cross_kv: Optional[Tuple] = None,
                     cross_len: Optional[int] = None,
                     backend: str = "reference"
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode.  x (B,1,D); cache {k,v}: (B,KH,C,dh) where C is
    either the full context or the sliding window (ring buffer).

    ``lengths`` (B,) = tokens generated so far (absolute position of the
    new token).  Returns (out (B,1,D), new_cache).
    """
    b = x.shape[0]
    group = cfg.n_heads // cfg.n_kv_heads
    scale = 1.0 / math.sqrt(cfg.dh)
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        kx, vx = cross_kv                       # (B,KH,T,dh)
        kx = jnp.repeat(kx, group, axis=1) if group > 1 else kx
        vx = jnp.repeat(vx, group, axis=1) if group > 1 else vx
        logits = jnp.einsum("bhk,bhsk->bhs", q, kx).astype(jnp.float32)
        logits = logits * scale
        if cross_len is not None:
            pos = jnp.arange(kx.shape[2])[None, None, :]
            logits = jnp.where(pos < cross_len, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhs,bhsk->bhk", w, vx)
        return (jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None],
                cache)

    q, k, v = _project_qkv(p, cfg, x, lengths[:, None])
    q = q[:, 0]                                  # (B,H,dh)
    knew = k[:, 0]                               # (B,KH,dh)
    vnew = v[:, 0]
    c = cache["k"].shape[2]
    slot = (lengths % c).astype(jnp.int32)       # ring position
    onehot = jax.nn.one_hot(slot, c, dtype=x.dtype)      # (B,C)
    kc = cache["k"] * (1 - onehot)[:, None, :, None] \
        + knew[:, :, None, :] * onehot[:, None, :, None]
    vc = cache["v"] * (1 - onehot)[:, None, :, None] \
        + vnew[:, :, None, :] * onehot[:, None, :, None]
    n_valid = jnp.minimum(lengths + 1, c)        # entries present
    if backend == "pallas":
        from repro.kernels import decode_attention

        out = decode_attention(q, kc, vc, n_valid,
                               window=window)    # (B,H,dh)
    else:
        kx = jnp.repeat(kc, group, axis=1) if group > 1 else kc
        vx = jnp.repeat(vc, group, axis=1) if group > 1 else vc
        logits = jnp.einsum("bhk,bhsk->bhs", q, kx).astype(jnp.float32)
        logits = logits * scale
        pos = jnp.arange(c)[None, None, :]
        valid = pos < n_valid[:, None, None]
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhs,bhsk->bhk", w, vx)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return y, {"k": kc, "v": vc}
