"""Quantized serving twins of the LM decode path (ROADMAP item 4).

Weight quantization is symmetric per-channel int8 or packed int4
(``core.quantize``): every weight matrix becomes a marker dict
``{"q8": int8, "qs": f32 scales}`` (or ``{"q4": packed bytes, "qs":
scales}``) that flows through jit/scan as an ordinary pytree — the
params resident in HBM are the quantized tree, and dequantization
happens per layer INSIDE the decode scan body, so at most one layer's
float weights exist at a time.  Scales reduce over the second-to-last
axis (the contraction-adjacent axis), which keeps them constant along
the contraction dim — exactly the invariant the Pallas
``dequant_matmul`` kernel needs to scale once per output element after
the int8 K-accumulation.

KV quantization is symmetric int8 with one f32 scale per head VECTOR
(``quantize_kv_heads``): the cache grows two scale leaves
(``k_scale``/``v_scale``, shape = cache shape minus the head dim) and
only the NEW token's K/V are quantized each step — written values are
never re-quantized, so a cache round-trip (snapshot/restore, paged
gather/scatter) is bit-exact and the compile-once serving contract
survives unchanged.

The quantized-vs-fp contract is tolerance-gated on logits
(docs/QUANTIZATION.md); quantized-vs-quantized across
admit/preempt/restore stays bit-identical, same as the fp engine.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import (INT4_MAX, INT4_MIN, INT8_MAX, INT8_MIN,
                                 dequantize_kv_heads, pack_int4,
                                 quantize_kv_heads, unpack_int4)
from repro.distributed.act_sharding import shard_act, shard_logits

from .common import ModelConfig, rms_norm
from .lm import (GATED_ACTS, _gate, _proj_qkv, decode_attention_block,
                 embed_tokens, mlp_block, moe_block,
                 paged_decode_attention_block)

Params = Dict[str, Any]

# The weight matrices worth quantizing — everything else (norm gains,
# the f32 MoE router, scalars) stays float: routing decisions are
# discrete and quantizing the router would flip them, breaking the
# tolerance contract for no memory win (the router is tiny).
QUANT_KEYS = frozenset({"wq", "wk", "wv", "wo", "wi", "wg",
                        "lm_head", "embed"})
WEIGHT_DTYPES = ("int8", "int4")
KV_DTYPES = ("int8",)


def is_qleaf(x: Any) -> bool:
    """Whether ``x`` is a quantized-weight marker dict."""
    return (isinstance(x, dict) and "qs" in x
            and ("q8" in x or "q4" in x))


def _quantize_leaf(w, bits: int) -> Dict[str, jnp.ndarray]:
    w = jnp.asarray(w).astype(jnp.float32)
    axis = max(w.ndim - 2, 0)
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    qmax = INT8_MAX if bits == 8 else INT4_MAX
    qmin = INT8_MIN if bits == 8 else INT4_MIN
    scales = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(w / scales), qmin, qmax).astype(jnp.int8)
    if bits == 4:
        return {"q4": pack_int4(q), "qs": scales}
    return {"q8": q, "qs": scales}


def quantize_lm_params(params: Params, cfg: ModelConfig,
                       weight_dtype: str) -> Params:
    """params -> the same tree with every QUANT_KEYS matrix replaced by
    its quantized marker dict.  An odd output-channel count falls back
    to int8 for that leaf (int4 packs channel pairs)."""
    if weight_dtype not in WEIGHT_DTYPES:
        raise ValueError(
            f"weight_dtype {weight_dtype!r} not in {WEIGHT_DTYPES}")
    bits = 8 if weight_dtype == "int8" else 4

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if key in QUANT_KEYS and getattr(val, "ndim", 0) >= 2:
                    b = 8 if (bits == 4 and val.shape[-1] % 2) else bits
                    out[key] = _quantize_leaf(val, b)
                else:
                    out[key] = walk(val)
            return out
        return node

    return walk(params)


def dequant_leaf(leaf: Dict[str, jnp.ndarray], dtype=jnp.float32):
    q = leaf["q8"] if "q8" in leaf else unpack_int4(leaf["q4"])
    return (q.astype(jnp.float32) * leaf["qs"]).astype(dtype)


def dequant_params(tree: Any, dtype=jnp.float32) -> Any:
    """Marker dicts -> float weights; non-quantized leaves unchanged."""
    return jax.tree.map(
        lambda x: dequant_leaf(x, dtype) if is_qleaf(x) else x,
        tree, is_leaf=is_qleaf)


# ---------------------------------------------------------------------------
# int8 KV cache (contiguous ring and paged pool share the layout)
# ---------------------------------------------------------------------------

def quantize_cache(cache: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """fp {k,v} -> {k, v int8, k_scale, v_scale f32} with one scale per
    head vector (last axis dropped).  Works on both the contiguous
    (L,B,KH,C,dh) ring and the paged (L,P,KH,BS,dh) pool; all-zero
    rows quantize to (0, scale 1.0) so empty caches stay exact."""
    kq, ks = quantize_kv_heads(cache["k"])
    vq, vs = quantize_kv_heads(cache["v"])
    return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}


def decode_attention_block_q(p: Params, cfg: ModelConfig, x,
                             ck, cv, cks, cvs, lengths, attn_impl=None):
    """int8-KV twin of ``decode_attention_block``: only the NEW token's
    K/V are quantized (scatter into the int8 ring + its scale ring);
    attention reads dequantize the cache.  ``attn_impl`` keeps the fp
    contiguous-kernel signature — it receives the dequantized cache.
    Returns (out, ck, cv, cks, cvs)."""
    b = x.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    g = h // kh
    c = ck.shape[2]
    q, k, v = _proj_qkv(p, cfg, x, lengths[:, None])
    kq, ks = quantize_kv_heads(k[:, 0])            # (B,KH,dh) / (B,KH)
    vq, vs = quantize_kv_heads(v[:, 0])
    slot = (lengths % c).astype(jnp.int32)
    rows = jnp.arange(b)
    ck = ck.at[rows, :, slot].set(kq)
    cv = cv.at[rows, :, slot].set(vq)
    cks = cks.at[rows, :, slot].set(ks)
    cvs = cvs.at[rows, :, slot].set(vs)
    n_valid = jnp.minimum(lengths + 1, c)
    kc = dequantize_kv_heads(ck, cks)
    vc = dequantize_kv_heads(cv, cvs)
    if attn_impl is not None:
        out = attn_impl(q[:, 0], kc, vc, n_valid).reshape(b, 1, h, dh)
    else:
        qg = q[:, 0].reshape(b, kh, g, dh)
        scale = 1.0 / math.sqrt(dh)
        logits = jnp.einsum("bkgd,bkcd->bkgc", qg, kc,
                            preferred_element_type=jnp.float32) * scale
        pos = jnp.arange(c)[None, None, None, :]
        valid = pos < n_valid[:, None, None, None]
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgc,bkcd->bkgd", w,
                         vc.astype(x.dtype)).reshape(b, 1, h, dh)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    return y, ck, cv, cks, cvs


def paged_decode_attention_block_q(p: Params, cfg: ModelConfig, x,
                                   pk, pv, pks, pvs, tables, lengths,
                                   attn_impl=None):
    """int8-KV twin of ``paged_decode_attention_block``: the pool and
    its per-row scales stay int8/f32 in HBM; ``attn_impl`` (the
    quantized block-table kernel) receives the RAW quantized pool —
    ``attn_impl(q, pk, pv, pks, pvs, tables, n_valid)`` — and
    dequantizes inside the kernel.  Returns (out, pk, pv, pks, pvs)."""
    b = x.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    g = h // kh
    bs = pk.shape[2]
    t = tables.shape[1]
    c = t * bs
    q, k, v = _proj_qkv(p, cfg, x, lengths[:, None])
    kq, ks = quantize_kv_heads(k[:, 0])
    vq, vs = quantize_kv_heads(v[:, 0])
    pos = (lengths % c).astype(jnp.int32)
    phys = jnp.take_along_axis(tables, (pos // bs)[:, None], axis=1)[:, 0]
    off = pos % bs
    pk = pk.at[phys, :, off].set(kq)
    pv = pv.at[phys, :, off].set(vq)
    pks = pks.at[phys, :, off].set(ks)
    pvs = pvs.at[phys, :, off].set(vs)
    n_valid = jnp.minimum(lengths + 1, c)
    if attn_impl is not None:
        out = attn_impl(q[:, 0], pk, pv, pks, pvs, tables,
                        n_valid).reshape(b, 1, h, dh)
    else:
        kc = dequantize_kv_heads(
            pk[tables].transpose(0, 2, 1, 3, 4).reshape(b, kh, c, dh),
            pks[tables].transpose(0, 2, 1, 3).reshape(b, kh, c))
        vc = dequantize_kv_heads(
            pv[tables].transpose(0, 2, 1, 3, 4).reshape(b, kh, c, dh),
            pvs[tables].transpose(0, 2, 1, 3).reshape(b, kh, c))
        qg = q[:, 0].reshape(b, kh, g, dh)
        scale = 1.0 / math.sqrt(dh)
        logits = jnp.einsum("bkgd,bkcd->bkgc", qg, kc,
                            preferred_element_type=jnp.float32) * scale
        posc = jnp.arange(c)[None, None, None, :]
        valid = posc < n_valid[:, None, None, None]
        logits = jnp.where(valid, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgc,bkcd->bkgd", w,
                         vc.astype(x.dtype)).reshape(b, 1, h, dh)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    return y, pk, pv, pks, pvs


# ---------------------------------------------------------------------------
# quantized decode steps (mirror lm_decode / lm_decode_paged)
# ---------------------------------------------------------------------------

def embed_tokens_q(params: Params, cfg: ModelConfig, tokens):
    e = params["embed"]
    if not is_qleaf(e):
        return embed_tokens(params, cfg, tokens)
    if "q8" in e:
        rows = jnp.take(e["q8"], tokens, axis=0)
    else:
        rows = unpack_int4(jnp.take(e["q4"], tokens, axis=0))
    out = (rows.astype(jnp.float32) * e["qs"]).astype(cfg.jnp_dtype())
    return shard_act(out)


def lm_logits_q(params: Params, cfg: ModelConfig, h) -> jnp.ndarray:
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = dequant_leaf(params["embed"], hn.dtype).T \
            if is_qleaf(params["embed"]) else params["embed"].T
    else:
        head = dequant_leaf(params["lm_head"], hn.dtype) \
            if is_qleaf(params["lm_head"]) else params["lm_head"]
    return shard_logits(jnp.einsum("bsd,dv->bsv", hn, head))


def mlp_block_q(p: Params, cfg: ModelConfig, x, mm=None) -> jnp.ndarray:
    """Quantized MLP: ``mm(x2d, qleaf) -> y2d`` is the weight-dequant
    matmul hook (the Pallas kernel via kernels/ops.py); without it the
    weights dequantize leaf-wise and the reference einsums run."""
    if mm is None:
        return mlp_block(dequant_params(p, x.dtype), cfg, x)
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    hidden = mm(x2, p["wi"])
    if cfg.act in GATED_ACTS:
        hidden = _gate(cfg.act, mm(x2, p["wg"])) * hidden
    else:
        hidden = jax.nn.gelu(hidden)
    out = mm(hidden.astype(x.dtype), p["wo"])
    return out.reshape(b, s, -1).astype(x.dtype)


def lm_decode_q(params: Params, cfg: ModelConfig, cache: Dict, tokens,
                lengths, *, data_shards: int = 16,
                embed_scale: Optional[float] = None, attn_impl=None,
                mlp_impl=None, kv_q: bool = False):
    """Quantized twin of ``lm_decode``: params is the marker-dict tree;
    per-layer weights dequantize inside the scan body.  When ``kv_q``
    the cache is the 4-leaf int8 layout of ``quantize_cache`` and
    ``attn_impl`` takes the fp-contiguous signature over a dequantized
    cache view."""
    dt = cfg.jnp_dtype()
    x = embed_tokens_q(params, cfg, tokens)
    if embed_scale is not None:
        x = x * jnp.asarray(embed_scale, x.dtype)

    def attend(p_attn, xin, kv):
        if kv_q:
            att, *new_kv = decode_attention_block_q(
                p_attn, cfg, xin, *kv, lengths, attn_impl=attn_impl)
        else:
            att, *new_kv = decode_attention_block(
                p_attn, cfg, xin, *kv, lengths, attn_impl=attn_impl)
        return att, tuple(new_kv)

    kv_keys = ("k", "v", "k_scale", "v_scale") if kv_q else ("k", "v")
    i0 = 0
    first_kv = None
    if "first_block" in params:
        fb = jax.tree.map(lambda a: a[0], params["first_block"])
        xin = rms_norm(x, fb["ln1"], cfg.norm_eps)
        att, first_kv = attend(dequant_params(fb["attn"], dt), xin,
                               tuple(cache[kk][0] for kk in kv_keys))
        h = x + att
        hin = rms_norm(h, fb["ln2"], cfg.norm_eps)
        x = h + mlp_block_q(fb["mlp"], cfg, hin, mm=mlp_impl)
        i0 = 1

    def body(h, layer_in):
        p_l = layer_in[0]
        xin = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        att, new_kv = attend(dequant_params(p_l["attn"], dt), xin,
                             layer_in[1:])
        hh = h + att
        hin = rms_norm(hh, p_l["ln2"], cfg.norm_eps)
        if "moe" in p_l:
            y, _ = moe_block(dequant_params(p_l["moe"], dt), cfg, hin,
                             data_shards)
        else:
            y = mlp_block_q(p_l["mlp"], cfg, hin, mm=mlp_impl)
        return hh + y, new_kv

    xs = (params["blocks"],) + tuple(cache[kk][i0:] for kk in kv_keys)
    x, outs = jax.lax.scan(body, x, xs)
    if i0:
        outs = tuple(jnp.concatenate([f[None], o])
                     for f, o in zip(first_kv, outs))
    logits = lm_logits_q(params, cfg, x)[:, 0]
    return logits, dict(zip(kv_keys, outs))


def lm_decode_paged_q(params: Params, cfg: ModelConfig, pool: Dict,
                      tables, tokens, lengths, *, data_shards: int = 16,
                      embed_scale: Optional[float] = None, attn_impl=None,
                      mlp_impl=None, kv_q: bool = False):
    """Quantized twin of ``lm_decode_paged``.  With ``kv_q`` the pool
    is the 4-leaf int8 layout and ``attn_impl`` is the quantized
    block-table kernel (raw pool + scales, in-kernel dequant)."""
    dt = cfg.jnp_dtype()
    x = embed_tokens_q(params, cfg, tokens)
    if embed_scale is not None:
        x = x * jnp.asarray(embed_scale, x.dtype)

    def attend(p_attn, xin, kv):
        if kv_q:
            att, *new_kv = paged_decode_attention_block_q(
                p_attn, cfg, xin, *kv, tables, lengths,
                attn_impl=attn_impl)
        else:
            att, *new_kv = paged_decode_attention_block(
                p_attn, cfg, xin, *kv, tables, lengths,
                attn_impl=attn_impl)
        return att, tuple(new_kv)

    kv_keys = ("k", "v", "k_scale", "v_scale") if kv_q else ("k", "v")
    i0 = 0
    first_kv = None
    if "first_block" in params:
        fb = jax.tree.map(lambda a: a[0], params["first_block"])
        xin = rms_norm(x, fb["ln1"], cfg.norm_eps)
        att, first_kv = attend(dequant_params(fb["attn"], dt), xin,
                               tuple(pool[kk][0] for kk in kv_keys))
        h = x + att
        hin = rms_norm(h, fb["ln2"], cfg.norm_eps)
        x = h + mlp_block_q(fb["mlp"], cfg, hin, mm=mlp_impl)
        i0 = 1

    def body(h, layer_in):
        p_l = layer_in[0]
        xin = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        att, new_kv = attend(dequant_params(p_l["attn"], dt), xin,
                             layer_in[1:])
        hh = h + att
        hin = rms_norm(hh, p_l["ln2"], cfg.norm_eps)
        if "moe" in p_l:
            y, _ = moe_block(dequant_params(p_l["moe"], dt), cfg, hin,
                             data_shards)
        else:
            y = mlp_block_q(p_l["mlp"], cfg, hin, mm=mlp_impl)
        return hh + y, new_kv

    xs = (params["blocks"],) + tuple(pool[kk][i0:] for kk in kv_keys)
    x, outs = jax.lax.scan(body, x, xs)
    if i0:
        outs = tuple(jnp.concatenate([f[None], o])
                     for f, o in zip(first_kv, outs))
    logits = lm_logits_q(params, cfg, x)[:, 0]
    return logits, dict(zip(kv_keys, outs))
