"""Expert-parallel MoE via shard_map + explicit all_to_all (§Perf C4).

The GSPMD einsum-dispatch path (lm.moe_block) lets the partitioner
choose the communication pattern; measured on qwen3-moe-30b-a3b
train_4k it falls back to THREE (G, T, D)-sized f32 collectives per
layer (~25 GB/device/layer) because the combine scatter-add cannot be
inferred as an all-to-all.  This module states the schedule explicitly:

  tokens  (per device: batch x seq shard)          [data, model]
    -> local top-k routing + capacity dispatch      (no comms)
    -> all_to_all over `model`: (E, C, D) -> (E/m, m*C, D)
    -> local expert FFN (weights all-gathered over `data` once: the
       FSDP gather, ~small vs activations)
    -> all_to_all back
    -> local combine (weighted scatter-add, T_loc-sized)

Per-device bytes moved ~ E*C_loc*D*2 per direction — the information-
theoretic minimum for capacity-based expert parallelism — instead of
the (G,T,D) all-reduce x3.  Differentiable end to end (all_to_all and
all_gather have transposes).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.act_sharding import _current as _act_ctx
from repro.models.common import ModelConfig


def ep_applicable(cfg: ModelConfig, b: int, s: int) -> bool:
    """shard_map EP path is usable for this call?"""
    ctx = _act_ctx()
    if ctx is None or not ctx.experts_divisible:
        return False
    mesh = ctx.mesh
    msz = mesh.shape.get("model", 1)
    dsz = 1
    for a in ("pod", "data"):
        dsz *= mesh.shape.get(a, 1)
    if msz <= 1:
        return False
    if not ctx.batch_divisible or b % dsz:
        return False
    if s % msz:
        return False
    if cfg.n_experts % msz:
        return False
    # local capacity must be a positive multiple of 4
    t_loc = (b // dsz) * (s // msz)
    return t_loc * cfg.top_k >= cfg.n_experts


def _local_dispatch(cfg: ModelConfig, x, router):
    """x (T,D) local tokens -> (xe (E,C,D), combine (E*C,), dispatch
    (E*C,) token ids, aux)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(4, -(-int(t * k * cfg.capacity_factor / e) // 4) * 4)
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    density = jnp.mean(jax.nn.one_hot(top_ids[..., 0], e), axis=0)
    aux = jnp.sum(density * jnp.mean(probs, axis=0)) * e

    flat_ids = top_ids.reshape(t * k)
    flat_w = top_w.reshape(t * k)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(t * k), flat_ids]
    keep = pos < cap
    slot = jnp.where(keep, flat_ids * cap + pos, e * cap)
    token_of = (jnp.arange(t * k) // k).astype(jnp.int32)
    dispatch = jnp.full((e * cap + 1,), t, jnp.int32)
    combine = jnp.zeros((e * cap + 1,), jnp.float32)
    dispatch = dispatch.at[slot].set(token_of, mode="drop")
    combine = combine.at[slot].set(flat_w, mode="drop")
    dispatch, combine = dispatch[:-1], combine[:-1]
    xpad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = jnp.take(xpad, dispatch, axis=0).reshape(e, cap, d)
    return xe, combine, dispatch, aux, cap


def moe_block_ep(p: Dict[str, Any], cfg: ModelConfig,
                 x: jnp.ndarray, n_valid=None,
                 eff_capacity=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in replacement for lm.moe_block when ep_applicable().

    The capacity-stable masked dispatch (``n_valid``/``eff_capacity``,
    serving's bucketed-MoE prefill — see ``lm.moe_dispatch``) is NOT
    supported here: ``_local_dispatch`` computes per-shard queue
    positions over locally contiguous token ranges, and a right-padded
    bucket would scatter real tokens across shards differently than
    the unpadded run.  ``lm.moe_block`` therefore keeps masked calls
    on the single-device path; this guard is the backstop."""
    if n_valid is not None or eff_capacity is not None:
        raise NotImplementedError(
            "capacity-stable masked MoE dispatch is single-device only "
            "(lm.moe_block routes it off the EP path)")
    ctx = _act_ctx()
    mesh = ctx.mesh
    msz = mesh.shape["model"]
    batch_axes = ctx.batch_axes
    b, s, d = x.shape
    e = cfg.n_experts
    e_loc = e // msz
    gated = cfg.act in ("silu", "geglu")

    def gate_fn(g):
        return jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)

    has_wg = "wg" in p["experts"]

    def local(x_loc, router, wi, wg, wo):
        # x_loc (B_loc, S_loc, D); expert weights arrive sharded E over
        # model and D over data -> gather D (the FSDP all-gather)
        for ax in reversed(batch_axes):
            wi = jax.lax.all_gather(wi, ax, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, ax, axis=2, tiled=True)
            if has_wg:
                wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
        bl, sl, _ = x_loc.shape
        xt = x_loc.reshape(bl * sl, d)
        xe, combine, dispatch, aux, cap = _local_dispatch(cfg, xt, router)
        # ---- all-to-all: experts to their owning shard --------------
        # (E, C, D) -> (E_loc, msz*C, D)
        xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1,
                                tiled=True)
        hid = jnp.einsum("ecd,edf->ecf", xe, wi)
        if gated:
            hid = gate_fn(jnp.einsum("ecd,edf->ecf", xe, wg)) * hid
        else:
            hid = jax.nn.gelu(hid)
        ye = jnp.einsum("ecf,efd->ecd", hid, wo)
        # ---- all-to-all back: (E_loc, msz*C, D) -> (E, C, D) ---------
        ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0,
                                tiled=True)
        ye = ye.reshape(e * cap, d) * combine[:, None].astype(ye.dtype)
        ypad = jnp.zeros((bl * sl + 1, d), ye.dtype)
        y = ypad.at[dispatch].add(ye)[:-1]
        aux = jax.lax.pmean(aux, ("model",) + tuple(batch_axes))
        return y.reshape(bl, sl, d), aux

    try:
        from jax import shard_map as _sm_mod  # jax >= 0.7 style
        shard_map = jax.shard_map
    except (ImportError, AttributeError):
        from jax.experimental.shard_map import shard_map

    dm = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    w_spec = P("model", dm, None)
    wo_spec = P("model", None, dm)
    wg_arg = p["experts"]["wg"] if has_wg \
        else jnp.zeros_like(p["experts"]["wi"])
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, "model", None), P(None, None),
                  w_spec, w_spec, wo_spec),
        out_specs=(P(batch_axes, "model", None), P()),
        check_rep=False)   # jax 0.4.x name; later releases call it check_vma
    y, aux = fn(x, p["router"], p["experts"]["wi"], wg_arg,
                p["experts"]["wo"])

    if cfg.n_shared_experts:
        from repro.models.lm import GATED_ACTS, _gate
        sh = p["shared"]
        hid = jnp.einsum("bsd,df->bsf", x, sh["wi"])
        if cfg.act in GATED_ACTS:
            hid = _gate(cfg.act, jnp.einsum("bsd,df->bsf", x, sh["wg"])) \
                * hid
        else:
            hid = jax.nn.gelu(hid)
        y = y + jnp.einsum("bsf,fd->bsd", hid, sh["wo"])
    return y, aux
