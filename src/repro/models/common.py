"""Shared model-config schema and primitive layers for the pod path.

Every assigned architecture is described by one ``ModelConfig``; builders
in lm.py / ssm.py / hybrid.py / encdec.py / vlm.py assemble families from
these primitives.  All parameters are plain dict pytrees; sharding specs
are produced by ``repro.distributed.sharding`` from the same config.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention details
    qk_norm: bool = False
    rope_base: float = 10000.0
    sliding_window: Optional[int] = None   # decode window for long_500k
    prefix_lm: bool = False                # PaliGemma-style prefix masking
    # activation / norm
    act: str = "silu"               # silu (SwiGLU) | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_layer_dense_ff: int = 0   # deepseek: dense layer 0
    capacity_factor: float = 1.25
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    # hybrid (Zamba2): shared attention block period
    shared_attn_every: int = 0
    # enc-dec (Whisper)
    n_encoder_layers: int = 0
    n_audio_ctx: int = 0            # encoder positions (stub frontend)
    # VLM (PaliGemma)
    n_vision_tokens: int = 0        # patch embeddings from the stub
    d_vision: int = 1152            # SigLIP-So400m width (stub output)
    # numerics
    dtype: str = "bfloat16"
    # provenance
    source: str = ""

    @property
    def dh(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytic parameter count (used for 6·N·D MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention block
        attn = d * self.n_heads * self.dh + 2 * d * self.n_kv_heads * self.dh \
            + self.n_heads * self.dh * d
        if self.family == "ssm":
            per_layer = self._ssm_block_params()
            total = emb + self.n_layers * per_layer
        elif self.family == "hybrid":
            mamba = self._ssm_block_params()
            shared = attn + 3 * d * self.d_ff + 4 * d
            n_shared_uses = (self.n_layers // self.shared_attn_every
                             if self.shared_attn_every else 0)
            total = emb + self.n_layers * mamba + shared
        elif self.family in ("moe",):
            moe = (self.n_experts * 3 * d * self.moe_d_ff
                   + self.n_shared_experts * 3 * d * self.moe_d_ff
                   + d * self.n_experts)
            total = emb + self.n_layers * (attn + moe + 2 * d)
            if self.first_layer_dense_ff:
                total += 3 * d * self.first_layer_dense_ff \
                    - (self.n_experts * 3 * d * self.moe_d_ff
                       + d * self.n_experts)
        else:
            ff_mult = 3 if self.act == "silu" else 2
            per_layer = attn + ff_mult * d * self.d_ff + 2 * d
            n_l = self.n_layers + self.n_encoder_layers
            total = emb + n_l * per_layer
            if self.n_encoder_layers:            # cross-attention
                total += self.n_layers * attn
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        active = self.n_layers * self.top_k * 3 * d * self.moe_d_ff
        return int(full - all_experts + active)

    def _ssm_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        n, g, h = self.ssm_state, self.ssm_groups, self.ssm_heads
        in_proj = d * (2 * di + 2 * g * n + h)
        conv = (di + 2 * g * n) * self.ssm_conv
        return in_proj + conv + 3 * h + di * d + d


# ---------------------------------------------------------------------------
# primitives (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps)).astype(x.dtype)
    return y * gamma.astype(x.dtype)


def layer_norm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
            * gamma.astype(x.dtype) + beta.astype(x.dtype))


def rope_cos_sin(positions: jnp.ndarray, dim: int, base: float,
                 dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int32 -> cos/sin (..., dim//2)."""
    half = dim // 2
    inv = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); cos/sin: (S, D//2), (..., S, D//2), or
    broadcastable with a head axis already in place.  A missing head
    axis is inserted — without it, per-slot decode positions of shape
    (B, 1, D//2) would right-align against (B, S, H, D//2) and rotate
    EVERY slot by slot 0's position (the preempt-to-a-different-slot
    tests in tests/test_preemption.py pin this down)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim < x.ndim:                  # (..., S, half): add head axis
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    c = cos.astype(x.dtype)
    s = sin.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def dense_init(key, shape, scale: Optional[float] = None,
               dtype=jnp.float32) -> jnp.ndarray:
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale
            ).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits (B,S,V) f32-upcast CE against labels (B,S).

    Written to stay vocab-sharded under GSPMD: ``take_along_axis`` over
    a model-sharded vocab axis makes the partitioner all-gather the
    full-vocab f32 logits per device (tens of GB at 200k vocab); the
    iota/where reduction and a hand-rolled logsumexp keep every (B,S,V)
    intermediate sharded and reduce to small (B,S) all-reduces.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = m[..., 0] + jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
