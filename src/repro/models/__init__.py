"""Pod-path model definitions for the ten assigned architectures."""

from .common import ModelConfig
from .registry import ModelBundle, get_model

__all__ = ["ModelConfig", "ModelBundle", "get_model"]
