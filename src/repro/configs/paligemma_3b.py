"""PaliGemma-3B [vlm] — SigLIP + Gemma (ViT stubbed)  [arXiv:2407.07726]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='paligemma-3b',
    family='vlm',
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    act='geglu',
    tie_embeddings=True,
    n_vision_tokens=256,
    d_vision=1152,
    prefix_lm=True,
    sliding_window=8192,
    source='arXiv:2407.07726',
)

REDUCED = ModelConfig(
    arch_id='paligemma-3b-smoke',
    family='vlm',
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=1,
    d_ff=512,
    vocab=512,
    head_dim=64,
    act='geglu',
    tie_embeddings=True,
    n_vision_tokens=16,
    d_vision=64,
    prefix_lm=True,
    dtype='float32',
    source='arXiv:2407.07726',
)
