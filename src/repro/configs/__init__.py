"""Assigned-architecture config registry (``--arch <id>``).

Ten architectures from the public pool, six families; every config
cites its source paper/model-card.  ``get_config(id)`` returns the
full assigned config, ``get_config(id, reduced=True)`` the smoke-test
variant (2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

_MODULES: Dict[str, str] = {
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "mamba2-780m": "mamba2_780m",
    "qwen3-32b": "qwen3_32b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "yi-6b": "yi_6b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "paligemma-3b": "paligemma_3b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-1.2b": "zamba2_1_2b",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.REDUCED if reduced else mod.CONFIG
