"""Whisper-large-v3 [audio] — enc-dec; conv frontend stubbed  [arXiv:2212.04356]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='whisper-large-v3',
    family='audio',
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    act='gelu',
    rope_base=0.0,
    n_encoder_layers=32,
    n_audio_ctx=1500,
    tie_embeddings=True,
    sliding_window=8192,
    source='arXiv:2212.04356',
)

REDUCED = ModelConfig(
    arch_id='whisper-large-v3-smoke',
    family='audio',
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    act='gelu',
    rope_base=0.0,
    n_encoder_layers=2,
    n_audio_ctx=32,
    tie_embeddings=True,
    dtype='float32',
    source='arXiv:2212.04356',
)
