"""Qwen3-32B [dense] — qk_norm, GQA  [hf:Qwen/Qwen3-8B]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='qwen3-32b',
    family='dense',
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    act='silu',
    rope_base=1000000.0,
    sliding_window=8192,
    source='hf:Qwen/Qwen3-8B',
)

REDUCED = ModelConfig(
    arch_id='qwen3-32b-smoke',
    family='dense',
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    head_dim=64,
    qk_norm=True,
    act='silu',
    dtype='float32',
    source='hf:Qwen/Qwen3-8B',
)
