"""Phi-4-mini 3.8B [dense]  [arXiv:2412.08905]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='phi4-mini-3.8b',
    family='dense',
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    act='silu',
    sliding_window=8192,
    source='arXiv:2412.08905',
)

REDUCED = ModelConfig(
    arch_id='phi4-mini-3.8b-smoke',
    family='dense',
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    act='silu',
    sliding_window=64,
    dtype='float32',
    source='arXiv:2412.08905',
)
