"""Zamba2-1.2B [hybrid] — Mamba2 + shared attn blocks  [arXiv:2411.15242]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='zamba2-1.2b',
    family='hybrid',
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    shared_attn_every=6,
    act='gelu',
    source='arXiv:2411.15242',
)

REDUCED = ModelConfig(
    arch_id='zamba2-1.2b-smoke',
    family='hybrid',
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    shared_attn_every=2,
    act='gelu',
    dtype='float32',
    source='arXiv:2411.15242',
)
