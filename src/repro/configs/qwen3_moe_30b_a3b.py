"""Qwen3-30B-A3B [moe] — 128 experts top-8  [hf:Qwen/Qwen3-30B-A3B]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='qwen3-moe-30b-a3b',
    family='moe',
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=0,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    act='silu',
    rope_base=1000000.0,
    sliding_window=8192,
    source='hf:Qwen/Qwen3-30B-A3B',
)

REDUCED = ModelConfig(
    arch_id='qwen3-moe-30b-a3b-smoke',
    family='moe',
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=0,
    vocab=512,
    head_dim=64,
    qk_norm=True,
    n_experts=4,
    top_k=2,
    moe_d_ff=128,
    act='silu',
    capacity_factor=8.0,
    dtype='float32',
    source='hf:Qwen/Qwen3-30B-A3B',
)
