"""DeepSeekMoE-16B [moe] — 2 shared + 64 routed top-6, fine-grained  [arXiv:2401.06066]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='deepseek-moe-16b',
    family='moe',
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab=102400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_layer_dense_ff=10944,
    act='silu',
    sliding_window=8192,
    source='arXiv:2401.06066',
)

REDUCED = ModelConfig(
    arch_id='deepseek-moe-16b-smoke',
    family='moe',
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=512,
    n_experts=4,
    top_k=2,
    n_shared_experts=1,
    moe_d_ff=128,
    first_layer_dense_ff=512,
    act='silu',
    capacity_factor=8.0,
    dtype='float32',
    source='arXiv:2401.06066',
)
