"""Phi-3-mini 3.8B [dense]  [arXiv:2404.14219]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='phi3-mini-3.8b',
    family='dense',
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    act='silu',
    sliding_window=8192,
    source='arXiv:2404.14219',
)

REDUCED = ModelConfig(
    arch_id='phi3-mini-3.8b-smoke',
    family='dense',
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab=512,
    act='silu',
    dtype='float32',
    source='arXiv:2404.14219',
)
