"""Mamba2-780m [ssm] — SSD (state-space duality)  [arXiv:2405.21060]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='mamba2-780m',
    family='ssm',
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    tie_embeddings=True,
    source='arXiv:2405.21060',
)

REDUCED = ModelConfig(
    arch_id='mamba2-780m-smoke',
    family='ssm',
    n_layers=2,
    d_model=256,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    tie_embeddings=True,
    dtype='float32',
    source='arXiv:2405.21060',
)
