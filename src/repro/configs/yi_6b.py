"""Yi-6B [dense] — llama-arch GQA  [arXiv:2403.04652]

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id='yi-6b',
    family='dense',
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    act='silu',
    rope_base=5000000.0,
    sliding_window=8192,
    source='arXiv:2403.04652',
)

REDUCED = ModelConfig(
    arch_id='yi-6b-smoke',
    family='dense',
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    act='silu',
    dtype='float32',
    source='arXiv:2403.04652',
)
