"""Public jit'd wrappers around the Pallas kernels + vendor-tag
registration.

This module is the "optimized kernel library" a hardware vendor ships
(§4.7): importing it registers ``tag="pallas"`` implementations with the
global op registry, so a resolver built with ``tags=("pallas",
"reference")`` transparently swaps them in — the TAGS="cmsis-nn" build
mechanism (§4.8), no interpreter changes.

Wrappers own layout/padding so kernels stay MXU-aligned:
  * quant_matmul pads (M, K, N) up to block multiples and precomputes the
    per-column weight sums for zero-point correction,
  * attention wrappers validate divisibility and choose block sizes.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as Q
from repro.core.op_resolver import PrepareResult, register_op
from repro.core.schema import OpCode

from .decode_attention import (decode_attention_pallas,
                               paged_decode_attention_pallas,
                               paged_decode_attention_q_pallas)
from .dequant_matmul import (dequant_matmul_i4_pallas,
                             dequant_matmul_pallas)
from .flash_attention import flash_attention_pallas
from .quant_matmul import quant_matmul_pallas
from .ssd_scan import ssd_scan_pallas

INTERPRET = True      # CPU container: validate kernels in interpret mode


def _pad_to(x: jnp.ndarray, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_block(size: int, pref: int = 128) -> int:
    if size % pref == 0:
        return pref
    for b in (64, 32, 16, 8):
        if size % b == 0:
            return b
    return size


# ---------------------------------------------------------------------------
# quantized matmul
# ---------------------------------------------------------------------------

def quant_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray,
                 bias_q: Optional[jnp.ndarray], x_zp: int,
                 scale: jnp.ndarray, out_zp: int,
                 interpret: bool = INTERPRET) -> jnp.ndarray:
    """int8 (M,K) @ (K,N) -> int8 (M,N); pads to MXU tiles internally."""
    m, k = x_q.shape
    _, n = w_q.shape
    bm, bk, bn = _pick_block(max(m, 8)), _pick_block(k), _pick_block(n)
    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_q, 0, bk), 1, bn)
    wsum = wp.astype(jnp.int32).sum(axis=0, keepdims=True)
    bias = (bias_q if bias_q is not None
            else jnp.zeros((n,), jnp.int32))
    biasp = _pad_to(bias.reshape(1, n).astype(jnp.int32), 1, bn)
    scalep = _pad_to(scale.reshape(1, n).astype(jnp.float32), 1, bn)
    out = quant_matmul_pallas(xp, wp, biasp, wsum, scalep,
                              x_zp=int(x_zp), out_zp=int(out_zp),
                              bm=bm, bk=bk, bn=bn, interpret=interpret)
    return out[:m, :n]


def dequant_matmul(x: jnp.ndarray, wleaf, interpret: bool = INTERPRET
                   ) -> jnp.ndarray:
    """f32 (M,K) @ quantized weight leaf (K,N) -> f32 (M,N).

    ``wleaf`` is a ``models.lm_quant`` marker dict — ``{"q8", "qs"}``
    or packed ``{"q4", "qs"}`` — with per-output-channel scales; the
    weight streams HBM→VMEM quantized and dequantizes inside the
    kernel.  Pads (M, K, N) to MXU tiles like ``quant_matmul``."""
    m, k = x.shape
    if "q8" in wleaf:
        w = wleaf["q8"]
        n = w.shape[-1]
    else:
        w = wleaf["q4"]
        n = w.shape[-1] * 2
    scale = wleaf["qs"].reshape(1, n)
    bm, bk, bn = _pick_block(max(m, 8)), _pick_block(k), _pick_block(n)
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    scalep = _pad_to(scale.astype(jnp.float32), 1, bn)
    if "q8" in wleaf:
        wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
        out = dequant_matmul_pallas(xp, wp, scalep, bm=bm, bk=bk, bn=bn,
                                    interpret=interpret)
    else:
        assert bn % 2 == 0, bn     # int4 leaves have even channel counts
        wp = _pad_to(_pad_to(w, 0, bk), 1, bn // 2)
        out = dequant_matmul_i4_pallas(xp, wp, scalep, bm=bm, bk=bk,
                                       bn=bn, interpret=interpret)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    interpret: bool = INTERPRET):
    s = q.shape[2]
    bq = bk = _pick_block(s)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, bq=bq, bk=bk,
                                  interpret=interpret)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     window: Optional[int] = None,
                     scale: Optional[float] = None,
                     interpret: bool = INTERPRET):
    s = k_cache.shape[2]
    bk = _pick_block(s)
    return decode_attention_pallas(q, k_cache, v_cache,
                                   jnp.asarray(lengths, jnp.int32),
                                   window=window, scale=scale, bk=bk,
                                   interpret=interpret)


def paged_decode_attention(q, k_pool, v_pool, tables, lengths, *,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           interpret: bool = INTERPRET):
    """Block-table decode attention: pools (P,KH,BS,D), tables (B,T).
    The kernel tile IS the KV block, so no block-size picking here —
    the pool's block size (chosen by the cost-model solver) decides."""
    return paged_decode_attention_pallas(
        q, k_pool, v_pool, jnp.asarray(tables, jnp.int32),
        jnp.asarray(lengths, jnp.int32), window=window, scale=scale,
        interpret=interpret)


def quant_paged_decode_attention(q, k_pool, v_pool, k_scales, v_scales,
                                 tables, lengths, *,
                                 window: Optional[int] = None,
                                 scale: Optional[float] = None,
                                 interpret: bool = INTERPRET):
    """int8-KV block-table decode attention: pools (P,KH,BS,D) int8
    with per-row scales (P,KH,BS) f32; dequant happens inside the
    kernel, after the HBM→VMEM stream (docs/QUANTIZATION.md)."""
    return paged_decode_attention_q_pallas(
        q, k_pool, v_pool, k_scales.astype(jnp.float32),
        v_scales.astype(jnp.float32), jnp.asarray(tables, jnp.int32),
        jnp.asarray(lengths, jnp.int32), window=window, scale=scale,
        interpret=interpret)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, D=None, *, chunk: Optional[int] = None,
             interpret: bool = INTERPRET):
    s = x.shape[1]
    if chunk is None:
        chunk = _pick_block(s)
    return ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                           interpret=interpret)


# ---------------------------------------------------------------------------
# vendor-tag registrations for the micro path (§4.8)
# ---------------------------------------------------------------------------

@register_op(OpCode.FULLY_CONNECTED, tag="pallas")
class PallasFullyConnected:
    """Optimized FC: int8 path runs on the quant_matmul Pallas kernel
    (MXU int8), float falls back to an einsum (XLA already fuses it)."""

    @staticmethod
    def prepare(ctx, op):
        from repro.core.micro_ops import FullyConnected
        return FullyConnected.prepare(ctx, op)

    @staticmethod
    def eval(ctx, op, inputs):
        x, w = inputs[0], inputs[1]
        bias = inputs[2] if len(inputs) > 2 and inputs[2] is not None \
            else None
        d = ctx.op_data
        if x.dtype == jnp.int8:
            rs: Q.RequantSpec = d["requant"]
            lead = x.shape[:-1]
            xm = x.reshape(-1, x.shape[-1])
            nchan = w.shape[0]
            real_scale = (rs.input_scale
                          * _weight_scales(rs, nchan) / rs.output_scale)
            out = quant_matmul(xm, w.T, bias, rs.input_zero_point,
                               jnp.asarray(real_scale, jnp.float32),
                               rs.output_zero_point)
            out = jnp.clip(out.astype(jnp.int32), d["qmin"], d["qmax"]
                           ).astype(jnp.int8)
            return [out.reshape(*lead, nchan)]
        acc = jnp.einsum("...k,nk->...n", x, w)
        if bias is not None:
            acc = acc + bias
        from repro.core.micro_ops import _apply_activation_f32
        return [_apply_activation_f32(acc, d["act"])]


def _weight_scales(rs: Q.RequantSpec, nchan: int) -> np.ndarray:
    """Recover per-channel weight scales from the requant spec: the spec
    stores M0/shift per channel of s_in*s_w/s_out."""
    real = (rs.multiplier.astype(np.float64) / (1 << 31)
            * np.exp2(rs.shift.astype(np.float64)))
    ws = real * rs.output_scale / rs.input_scale
    if ws.shape[0] == 1 and nchan > 1:
        ws = np.repeat(ws, nchan)
    return ws.astype(np.float32)


@register_op(OpCode.ATTENTION, tag="pallas")
class PallasAttention:
    @staticmethod
    def prepare(ctx, op):
        from repro.core.micro_ops import Attention
        return Attention.prepare(ctx, op)

    @staticmethod
    def eval(ctx, op, inputs):
        q, k, v = inputs
        return [flash_attention(q, k, v,
                                causal=op.params.get("causal", True))]


# ---------------------------------------------------------------------------
# vendor-tag registration for the SERVING path (§4.8 at pod scale)
# ---------------------------------------------------------------------------

@register_op(OpCode.SERVING_DECODE, tag="pallas")
class PallasServingDecode:
    """Optimized pod-scale decode step: per-layer attention runs on the
    flash-decoding Pallas kernel for dense-KV families.  prepare()
    inspects the model family once at engine init and bakes the choice
    into op_data — families without a dense (B,KH,C,dh) cache (SSM,
    hybrid) fall back to the bundle's reference decode, the per-kernel
    fallback the tag chain promises."""

    @staticmethod
    def prepare(ctx, op):
        cfg = ctx.bundle.cfg
        use_kernel = cfg.family in ("dense", "moe")
        return PrepareResult(output_specs=[],
                             op_data={"use_kernel": use_kernel})

    @staticmethod
    def eval(ctx, op, inputs):
        params, cache, tokens, lengths = inputs
        if not ctx.op_data["use_kernel"]:
            return ctx.bundle.decode(params, cache, tokens, lengths,
                                     window=op.params.get("window"))
        from repro.models import lm
        # no window= here on purpose: the dense-family reference decode
        # (lm_decode) attends over the whole valid cache, so the vendor
        # kernel must too — tag choice may never change semantics
        return lm.lm_decode(params, ctx.bundle.cfg, cache, tokens,
                            lengths, attn_impl=decode_attention)


@register_op(OpCode.SERVING_DECODE_PAGED, tag="pallas")
class PallasServingDecodePaged:
    """Optimized paged decode step: per-layer attention walks the slot's
    block table with the scalar-prefetch Pallas kernel for dense-KV
    transformer families (dense/moe).  The vlm family shares the same
    paged model step but keeps reference attention (as on the
    contiguous path), with the embed scale baked at prepare time."""

    @staticmethod
    def prepare(ctx, op):
        import math as _math
        # imported lazily: kernels layers beneath the serving package
        from repro.serving.errors import UnsupportedFamilyError
        cfg = ctx.bundle.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise UnsupportedFamilyError(
                cfg.family, "paged KV (requires a dense (KH, C, dh) "
                            "cache layout)",
                supported=("dense", "moe", "vlm"))
        scale = _math.sqrt(cfg.d_model) if cfg.family == "vlm" else None
        use_kernel = cfg.family in ("dense", "moe")
        return PrepareResult(output_specs=[],
                             op_data={"use_kernel": use_kernel,
                                      "embed_scale": scale})

    @staticmethod
    def eval(ctx, op, inputs):
        params, pool, tables, tokens, lengths = inputs
        from repro.models import lm
        impl = paged_decode_attention if ctx.op_data["use_kernel"] else None
        return lm.lm_decode_paged(params, ctx.bundle.cfg, pool, tables,
                                  tokens, lengths,
                                  embed_scale=ctx.op_data["embed_scale"],
                                  attn_impl=impl)


@register_op(OpCode.SERVING_DECODE_Q, tag="pallas")
class PallasServingDecodeQ:
    """Optimized quantized decode step: dense/moe MLP matmuls run on
    the weight-dequant Pallas kernel (``dequant_matmul`` — int8 or
    packed-int4 weights stream HBM→VMEM quantized, dequantize in the
    kernel), and attention runs on the flash-decoding kernels — the
    int8-KV paged combination uses the block-table kernel that
    dequantizes INSIDE the kernel body.  vlm keeps reference attention
    (as on the fp path) but still decodes through the per-layer-dequant
    quantized model step; recurrent families fall back to the
    reference quantized decode, the per-kernel fallback the tag chain
    promises.  There is deliberately no pallas SERVING_PREFILL_Q:
    prefill is compute-bound, so the tag chain's reference fallback IS
    the optimized choice there."""

    @staticmethod
    def prepare(ctx, op):
        # imported lazily: kernels layers beneath the serving package
        from repro.serving.ops import _quant_family_gate
        od = _quant_family_gate(ctx.bundle.cfg, op)
        od["use_kernel"] = ctx.bundle.cfg.family in ("dense", "moe")
        # a KV-only engine keeps fp weight leaves — the dequant matmul
        # would have nothing to dequantize, so the MLP hook stays off
        od["use_mm"] = (od["use_kernel"]
                        and od["weight_dtype"] in ("int8", "int4"))
        return PrepareResult(output_specs=[], op_data=od)

    @staticmethod
    def eval(ctx, op, inputs):
        from repro.models.lm_quant import (dequant_params, lm_decode_q,
                                           lm_decode_paged_q)
        cfg = ctx.bundle.cfg
        od = ctx.op_data
        mm = dequant_matmul if od["use_mm"] else None
        if od["paged"]:
            params, pool, tables, tokens, lengths = inputs
            if od["use_kernel"]:
                attn = (quant_paged_decode_attention if od["kv_q"]
                        else paged_decode_attention)
            else:
                attn = None
            return lm_decode_paged_q(
                params, cfg, pool, tables, tokens, lengths,
                embed_scale=od["scale"], kv_q=od["kv_q"],
                attn_impl=attn, mlp_impl=mm)
        params, cache, tokens, lengths = inputs
        if od["lm_path"]:
            attn = decode_attention if od["use_kernel"] else None
            return lm_decode_q(params, cfg, cache, tokens, lengths,
                               embed_scale=od["scale"], kv_q=od["kv_q"],
                               attn_impl=attn, mlp_impl=mm)
        fp = dequant_params(params, cfg.jnp_dtype())
        return ctx.bundle.decode(fp, cache, tokens, lengths,
                                 window=op.params.get("window"))
