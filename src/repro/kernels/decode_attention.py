"""Decode attention Pallas kernel — one new token vs. a long KV cache.

Flash-decoding adapted to TPU: the KV cache streams HBM→VMEM in (BK, D)
tiles along a sequential grid axis; the single query row stays resident
in VMEM for the whole pass; the online-softmax carry lives in VMEM
scratch.  Variable sequence lengths and the sliding window are handled by
masking against a per-batch ``lengths`` vector in SMEM — out-of-range and
out-of-window tiles are skipped with ``pl.when`` so a 512k-entry cache at
window 8k touches only ~window/BK tiles of compute.

This kernel is the long-context serving hot spot (decode_32k, long_500k
input shapes).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_BK = 128
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   n_k: int, bk: int, scale: float,
                   window: Optional[int]):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[pl.program_id(0)]
    k_start = ik * bk
    needed = k_start < length
    if window is not None:
        needed = jnp.logical_and(needed, k_start + bk > length - window)

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)           # (H_blk, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                  # (H_blk, BK)
        pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = pos < length
        if window is not None:
            mask &= pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = (l_ref[...][:, 0] * alpha + p.sum(axis=1))[:, None]
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[...][:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "bk", "interpret"))
def decode_attention_pallas(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, lengths: jnp.ndarray,
                            *, window: Optional[int] = None,
                            scale: Optional[float] = None,
                            bk: int = DEF_BK,
                            interpret: bool = True) -> jnp.ndarray:
    """q (B,H,D), caches (B,KH,S,D), lengths (B,) int32 -> (B,H,D).

    All H query heads of one KV head are processed as one (group, D) tile
    so the MXU matmul has a real M dimension even at batch decode.
    """
    b, h, d = q.shape
    kh, s = k_cache.shape[1], k_cache.shape[2]
    group = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    assert s % bk == 0, (s, bk)
    n_k = s // bk
    qg = q.reshape(b, kh, group, d)
    grid = (b, kh, n_k)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_k=n_k, bk=bk, scale=scale,
                          window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM),
            pl.BlockSpec((1, 1, group, d), lambda b_, h_, ik: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, ik: (b_, h_, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, ik: (b_, h_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda b_, h_, ik: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(b, h, d)


# ---------------------------------------------------------------------------
# paged decode attention — walk a block table instead of contiguous rows
# ---------------------------------------------------------------------------

def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         n_t: int, bs: int, scale: float,
                         window: Optional[int]):
    """Same online-softmax body as ``_decode_kernel``: the block table
    is consumed by the index_maps (scalar prefetch), so by the time this
    body runs k_ref/v_ref already hold the RIGHT physical block — the
    kernel only needs the logical tile index for masking."""
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[pl.program_id(0)]
    k_start = it * bs
    needed = k_start < length
    if window is not None:
        needed = jnp.logical_and(needed, k_start + bs > length - window)

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)           # (group, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (BS, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                  # (group, BS)
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        if window is not None:
            mask &= pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = (l_ref[...][:, 0] * alpha + p.sum(axis=1))[:, None]
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]

    @pl.when(it == n_t - 1)
    def _finish():
        l = l_ref[...][:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_q_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref,
                           ks_ref, vs_ref, o_ref,
                           m_ref, l_ref, acc_ref, *,
                           n_t: int, bs: int, scale: float,
                           window: Optional[int]):
    """Int8-KV twin of ``_paged_decode_kernel``: the pool blocks arrive
    as int8 rows plus one f32 scale per (block row, KV head) vector,
    and the dequant ``k = q8 * s`` happens HERE, after the HBM→VMEM
    stream — so the HBM traffic per tile is the int8 payload, not the
    f32 one.  Everything downstream (masking, online softmax) is the
    exact float math of the unquantized kernel."""
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[pl.program_id(0)]
    k_start = it * bs
    needed = k_start < length
    if window is not None:
        needed = jnp.logical_and(needed, k_start + bs > length - window)

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)           # (group, D)
        ks = ks_ref[0, 0].astype(jnp.float32)         # (BS,)
        vs = vs_ref[0, 0].astype(jnp.float32)         # (BS,)
        k = k_ref[0, 0].astype(jnp.float32) * ks[:, None]   # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32) * vs[:, None]   # (BS, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                  # (group, BS)
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        if window is not None:
            mask &= pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = (l_ref[...][:, 0] * alpha + p.sum(axis=1))[:, None]
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]

    @pl.when(it == n_t - 1)
    def _finish():
        l = l_ref[...][:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "interpret"))
def paged_decode_attention_q_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                                    v_pool: jnp.ndarray,
                                    k_scales: jnp.ndarray,
                                    v_scales: jnp.ndarray,
                                    tables: jnp.ndarray,
                                    lengths: jnp.ndarray,
                                    *, window: Optional[int] = None,
                                    scale: Optional[float] = None,
                                    interpret: bool = True) -> jnp.ndarray:
    """q (B,H,D) f32, pools (P,KH,BS,D) int8, scales (P,KH,BS) f32,
    tables (B,T) int32, lengths (B,) int32 -> (B,H,D).

    The int8-KV variant of ``paged_decode_attention_pallas``: same
    scalar-prefetch block-table indirection, with two extra per-block
    scale inputs riding the SAME index_maps as K/V so each physical
    block's scales stream alongside its rows.  Dequantization happens
    inside the kernel body (see ``_paged_decode_q_kernel``) — the
    arena stays int8 end to end and HBM reads shrink accordingly."""
    b, h, d = q.shape
    _, kh, bs, _ = k_pool.shape
    t = tables.shape[1]
    group = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kh, group, d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, t),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (tbl_ref[b_, it], h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (tbl_ref[b_, it], h_, 0, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (tbl_ref[b_, it], h_, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (tbl_ref[b_, it], h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda b_, h_, it, tbl_ref, len_ref:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_q_kernel, n_t=t, bs=bs,
                          scale=scale, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, group, d), q.dtype),
        interpret=interpret,
    )(tables, lengths, qg, k_pool, v_pool, k_scales, v_scales)
    return out.reshape(b, h, d)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "interpret"))
def paged_decode_attention_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                                  v_pool: jnp.ndarray,
                                  tables: jnp.ndarray,
                                  lengths: jnp.ndarray,
                                  *, window: Optional[int] = None,
                                  scale: Optional[float] = None,
                                  interpret: bool = True) -> jnp.ndarray:
    """q (B,H,D), pools (P,KH,BS,D), tables (B,T) int32, lengths (B,)
    int32 -> (B,H,D).

    The gather never materialises: ``tables`` rides the scalar-prefetch
    lane (``PrefetchScalarGridSpec``), and the K/V index_maps read
    ``tbl_ref[b, it]`` to pick WHICH physical block streams HBM→VMEM
    for logical tile ``it`` — block-table indirection at DMA-issue
    time, zero extra HBM traffic vs. the contiguous kernel.  The tile
    size is pinned to the pool's block size, so the cost model's block
    solver is also choosing this kernel's tile.  Unmapped table entries
    point at the pool's garbage block 0; they sit past ``lengths`` and
    are skipped by the ``pl.when(needed)`` guard.
    """
    b, h, d = q.shape
    _, kh, bs, _ = k_pool.shape
    t = tables.shape[1]
    group = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kh, group, d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, t),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (tbl_ref[b_, it], h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, it, tbl_ref, len_ref:
                         (tbl_ref[b_, it], h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda b_, h_, it, tbl_ref, len_ref:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, n_t=t, bs=bs, scale=scale,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, group, d), q.dtype),
        interpret=interpret,
    )(tables, lengths, qg, k_pool, v_pool)
    return out.reshape(b, h, d)
