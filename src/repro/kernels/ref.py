"""Pure-jnp oracles for every Pallas kernel.

These are the "reference kernels" in the paper's sense (§4.7: readable,
portable, correctness-first).  Every Pallas kernel's test sweeps
shapes/dtypes and asserts allclose against the function here.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# quantized matmul (the CMSIS-NN FC/conv-core analogue)
# ---------------------------------------------------------------------------

def quant_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray,
                     bias_q: Optional[jnp.ndarray],
                     x_zp: int, scale: jnp.ndarray,
                     out_zp: int) -> jnp.ndarray:
    """int8 (M,K) @ int8 (K,N) -> int8 (M,N).

    acc = sum_k (x - x_zp) * w + bias;  out = clip(round(acc*scale)+zp).
    ``scale`` is f32 per output channel (s_x*s_w[n]/s_out).
    """
    acc = jax.lax.dot_general(
        x_q.astype(jnp.int32) - jnp.int32(x_zp), w_q.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    if bias_q is not None:
        acc = acc + bias_q.astype(jnp.int32)[None, :]
    out = jnp.round(acc.astype(jnp.float32) * scale[None, :]) + out_zp
    return jnp.clip(out, -128, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# flash attention (prefill) — causal, GQA, optional sliding window
# ---------------------------------------------------------------------------

def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True,
            window: Optional[int] = None,
            scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, KH, S, D) with H % KH == 0 (GQA).

    window=W restricts key j to q position i: i - W < j <= i.
    """
    b, h, s, d = q.shape
    kh = k.shape[1]
    group = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      vx.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention — one new token against a KV cache
# ---------------------------------------------------------------------------

def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, lengths: jnp.ndarray,
                         window: Optional[int] = None,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, D); caches: (B, KH, S, D); lengths: (B,) valid entries.

    Returns (B, H, D).  With window=W only the last W valid positions
    attend (sliding-window / sub-quadratic long-context decode).
    """
    b, h, d = q.shape
    kh, s = k_cache.shape[1], k_cache.shape[2]
    group = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    kx = jnp.repeat(k_cache, group, axis=1)
    vx = jnp.repeat(v_cache, group, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    pos = jnp.arange(s)[None, :]
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos >= (lengths[:, None] - window)
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", w,
                      vx.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray, tables: jnp.ndarray,
                               lengths: jnp.ndarray,
                               window: Optional[int] = None,
                               scale: Optional[float] = None) -> jnp.ndarray:
    """Paged twin of :func:`decode_attention_ref`.

    q: (B, H, D); pools: (P, KH, BS, D) — the shared physical block
    pool; tables: (B, T) int32 physical block ids in logical order
    (T*BS = logical cache length, unmapped tail entries point at the
    pool's garbage block 0); lengths: (B,) valid entries.

    Gathers each row's blocks back to a contiguous (B, KH, T*BS, D)
    view and delegates to the contiguous oracle, so a paged cache whose
    gathered view equals a contiguous cache produces bit-identical
    output (garbage-block rows sit past ``lengths`` and get exactly
    zero softmax weight).
    """
    _, kh, bs, d = k_pool.shape
    b, t = tables.shape
    kc = k_pool[tables].transpose(0, 2, 1, 3, 4).reshape(b, kh, t * bs, d)
    vc = v_pool[tables].transpose(0, 2, 1, 3, 4).reshape(b, kh, t * bs, d)
    return decode_attention_ref(q, kc, vc, lengths,
                                window=window, scale=scale)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) — sequential oracle
# ---------------------------------------------------------------------------

def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            B: jnp.ndarray, C: jnp.ndarray, D: Optional[jnp.ndarray],
            h0: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Selective state-space recurrence (Mamba-2, arXiv:2405.21060).

      h_t = exp(dt_t A_h) * h_{t-1} + dt_t * x_t ⊗ B_t
      y_t = C_t · h_t (+ D_h x_t)

    Shapes: x (B,S,H,P); dt (B,S,H); A (H,) negative reals;
            B, C (B,S,G,N) with H % G == 0; D (H,) or None;
            h0 (B,H,P,N) or None.
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    group = h // g
    Bh = jnp.repeat(B, group, axis=2)            # (B,S,H,N)
    Ch = jnp.repeat(C, group, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(hstate, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P),(B,H),(B,H,N)x2
        decay = jnp.exp(dtt * A[None, :])         # (B,H)
        upd = (dtt[..., None, None] * xt[..., :, None]
               * bt[..., None, :])                # (B,H,P,N)
        hstate = hstate * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bhn->bhp", hstate, ct)
        return hstate, yt

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    inputs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
              Bh.astype(jnp.float32).transpose(1, 0, 2, 3),
              Ch.astype(jnp.float32).transpose(1, 0, 2, 3))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), inputs)
    y = ys.transpose(1, 0, 2, 3)
    if D is not None:
        y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), hT
