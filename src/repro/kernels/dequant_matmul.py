"""Weight-dequant matmul Pallas kernels — the serving-side quantized
matmul (ROADMAP item 4, docs/QUANTIZATION.md).

The micro path's ``quant_matmul.py`` computes in int8 end to end
(int8×int8→int32 MXU, requantize to int8) because micro activations are
themselves quantized.  Pod decode is different: activations stay float
(the logit tolerance contract is against the fp engine), and the win is
memory-bound — weights stream HBM→VMEM as int8 or packed int4 and are
dequantized INSIDE the kernel, tile by tile, so the full-precision
weight matrix never exists in HBM.  Scales are symmetric per output
channel, so dequant commutes with the K-accumulation and is applied
once per output element at the final K step:

    Σ_k x_k · (q_kj · s_j)  ==  s_j · Σ_k x_k · q_kj

The int4 variant unpacks two nibbles per streamed byte in VMEM
(arithmetic-shift sign extension, same packing as
``core.quantize.pack_int4``), halving weight HBM traffic again.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-aligned default tile (matches quant_matmul.py)
DEF_BM, DEF_BK, DEF_BN = 128, 128, 128


def _dequant_matmul_kernel(x_ref, w_ref, scale_ref, out_ref, acc_ref,
                           *, n_k: int):
    """Grid: (M/bm, N/bn, K/bk) — K innermost, sequential accumulation.
    ``w_ref`` holds an int8 tile; the cast to f32 happens here, after
    the HBM→VMEM stream."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] * scale_ref[...]).astype(
            out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn",
                                             "interpret"))
def dequant_matmul_pallas(x: jnp.ndarray, w_q: jnp.ndarray,
                          scale: jnp.ndarray, *,
                          bm: int = DEF_BM, bk: int = DEF_BK,
                          bn: int = DEF_BN,
                          interpret: bool = True) -> jnp.ndarray:
    """x (M,K) f32 · w_q (K,N) int8, scale (1,N) f32 → f32 (M,N).

    M, K, N must be multiples of (bm, bk, bn) — ops.py pads.
    """
    m, k = x.shape
    _, n = w_q.shape
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), w_q, scale.astype(jnp.float32))


def _dequant_matmul_i4_kernel(x_ref, wp_ref, scale_ref, out_ref,
                              acc_ref, *, n_k: int, bn: int):
    """int4 twin: ``wp_ref`` is a (bk, bn//2) tile of packed bytes —
    unpack in VMEM (sign-extending arithmetic shifts, the inverse of
    ``core.quantize.pack_int4``) then the same f32 MXU accumulation."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wp = wp_ref[...]                               # (bk, bn//2) int8
    lo = ((wp << 4) >> 4).astype(jnp.float32)
    hi = (wp >> 4).astype(jnp.float32)
    w = jnp.stack([lo, hi], axis=-1).reshape(wp.shape[0], bn)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] * scale_ref[...]).astype(
            out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn",
                                             "interpret"))
def dequant_matmul_i4_pallas(x: jnp.ndarray, w_p: jnp.ndarray,
                             scale: jnp.ndarray, *,
                             bm: int = DEF_BM, bk: int = DEF_BK,
                             bn: int = DEF_BN,
                             interpret: bool = True) -> jnp.ndarray:
    """x (M,K) f32 · packed-int4 w_p (K,N/2) int8, scale (1,N) f32
    → f32 (M,N).  Packing is along the output-channel axis (pairs of
    adjacent columns share a byte), so a (bk, bn//2) byte tile unpacks
    to exactly one (bk, bn) weight tile.  ``bn`` must be even."""
    m, k = x.shape
    _, n_half = w_p.shape
    n = n_half * 2
    assert bn % 2 == 0, bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_i4_kernel, n_k=n_k, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), w_p, scale.astype(jnp.float32))
