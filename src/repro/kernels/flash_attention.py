"""Flash attention (prefill) Pallas kernel — causal, GQA, sliding window.

TPU adaptation notes (DESIGN.md §2): the GPU flash-attention formulation
(warp-level softmax reductions, shared-memory tiles) maps onto TPU as
VMEM-resident (BQ, BK) score tiles produced by MXU block matmuls with the
online-softmax carry (m, l, acc) held in VMEM scratch across the
sequential K grid dimension.  Q/K/V tiles stream HBM→VMEM via BlockSpec;
block sizes default to 128 (MXU-aligned).

GQA is expressed in the BlockSpec index maps: the K/V block index divides
the query-head index by the group size, so no repeated-KV materialization
ever happens (the repeat in ref.py is the readable-reference trade-off).

Causal/out-of-window key blocks are skipped with ``pl.when`` — the block
is still fetched (BlockSpec prefetch is unconditional) but contributes no
FLOPs; a production kernel would shrink the grid instead, which we do in
the wrapper by clamping the K grid to the causal frontier when the whole
row block is masked.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_BQ, DEF_BK = 128, 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, bq: int, bk: int, scale: float, causal: bool,
                  window: Optional[int]):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    def _needed():
        if not causal and window is None:
            return True
        need = True
        if causal:
            need = jnp.logical_and(need, k_start <= q_start + bq - 1)
        if window is not None:
            need = jnp.logical_and(need,
                                   k_start + bk - 1 > q_start - window)
        return need

    @pl.when(_needed())
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                 # (BQ, BK)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]                     # (BQ,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_ref[...][:, 0] * alpha + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[...][:, 0]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           bq: int = DEF_BQ, bk: int = DEF_BK,
                           interpret: bool = True) -> jnp.ndarray:
    """q (B,H,S,D), k/v (B,KH,S,D) -> (B,H,S,D).  S % bq == S % bk == 0."""
    b, h, s, d = q.shape
    kh = k.shape[1]
    group = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_k = s // bk
    grid = (b, h, s // bq, n_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, n_k=n_k, bq=bq, bk=bk,
                          scale=scale, causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m
            pltpu.VMEM((bq, 1), jnp.float32),    # l
            pltpu.VMEM((bq, d), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
