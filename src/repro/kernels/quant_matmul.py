"""INT8 matmul Pallas kernel — the TPU-native CMSIS-NN analogue (§4.7–4.8).

CMSIS-NN accelerates TFLM's int8 FC/conv inner loops with Cortex-M SIMD;
the TPU-native equivalent is an MXU int8 matmul with int32 accumulation,
VMEM-tiled with 128-aligned blocks.  Requantization back to int8 happens
in f32 inside the kernel (one multiply per output element) — the MXU
pipeline has no 64-bit scalar path, so gemmlowp's Q31
doubling-high-multiply is replaced by f32 scaling; tests bound the
difference against the bit-exact reference at ≤1 LSB.

Zero-point handling is factored out of the inner loop exactly like the
optimized CMSIS kernels: acc = Σ x_q·w_q − x_zp·Σ w_q, with the per-column
weight sums precomputed by the wrapper (ops.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-aligned default tile (128×128 systolic array; int8 native lane=128)
DEF_BM, DEF_BK, DEF_BN = 128, 128, 128


def _quant_matmul_kernel(x_ref, w_ref, bias_ref, wsum_ref, scale_ref,
                         out_ref, acc_ref, *, n_k: int, x_zp: int,
                         out_zp: int):
    """Grid: (M/bm, N/bn, K/bk) — K innermost, sequential accumulation."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU int8×int8→int32 block product
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        acc = acc_ref[...]
        # zero-point correction: − x_zp * Σ_k w[k, n]
        acc = acc - jnp.int32(x_zp) * wsum_ref[...]
        acc = acc + bias_ref[...]
        scaled = jnp.round(acc.astype(jnp.float32) * scale_ref[...])
        out = scaled + jnp.float32(out_zp)
        out_ref[...] = jnp.clip(out, -128.0, 127.0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("x_zp", "out_zp", "bm", "bk",
                                             "bn", "interpret"))
def quant_matmul_pallas(x_q: jnp.ndarray, w_q: jnp.ndarray,
                        bias_q: jnp.ndarray, wsum: jnp.ndarray,
                        scale: jnp.ndarray, *, x_zp: int, out_zp: int,
                        bm: int = DEF_BM, bk: int = DEF_BK,
                        bn: int = DEF_BN,
                        interpret: bool = True) -> jnp.ndarray:
    """x_q (M,K) int8 · w_q (K,N) int8 → int8 (M,N).

    bias_q (1,N) int32, wsum (1,N) int32 = Σ_k w_q, scale (1,N) f32.
    M, K, N must be multiples of (bm, bk, bn) — ops.py pads.
    """
    m, k = x_q.shape
    _, n = w_q.shape
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, n_k=n_k, x_zp=x_zp,
                          out_zp=out_zp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, bias_q, wsum, scale)
