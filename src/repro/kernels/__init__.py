"""Optimized Pallas TPU kernels — the CMSIS-NN/Cadence vendor-library
analogue (paper §4.7–4.8).  Importing ``repro.kernels.ops`` registers the
``tag="pallas"`` implementations with the op registry; ``ref.py`` holds
the pure-jnp oracles every kernel is validated against.

Kernels (each: <name>.py with pl.pallas_call + explicit BlockSpec VMEM
tiling; validated with interpret=True on CPU, TPU is the target):

  * quant_matmul     — int8 MXU matmul + requant (the TFLM hot spot)
  * flash_attention  — causal/GQA/sliding-window prefill attention
  * decode_attention — flash-decoding over long KV caches
  * ssd_scan         — Mamba-2 state-space-duality chunked scan
"""

from .ops import (decode_attention, flash_attention, quant_matmul,
                  ssd_scan)

__all__ = ["decode_attention", "flash_attention", "quant_matmul",
           "ssd_scan"]
