"""Mamba-2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The paper's GPU kernel (Dao & Gu, arXiv:2405.21060) splits the sequence
into chunks: inside a chunk the recurrence is computed in its *dual*
quadratic-attention form (three MXU matmuls over an (L, L) decay-masked
score tile), and between chunks only the (P, N) state is carried.

TPU adaptation: the chunk axis is the innermost sequential grid
dimension; the carried state lives in VMEM scratch (grid steps on a TPU
core run in order, so scratch persists across chunk iterations — the
TPU-native substitute for the GPU kernel's cross-block shared-memory
pipeline).  Chunk tiles (L×P, L×N) stream HBM→VMEM via BlockSpec; L and
N default to 128 to keep the three matmuls MXU-shaped.  No collectives:
sequence stays on-chip, which is why SSM archs shard heads, not sequence
(DESIGN.md §6).

Recurrence (per batch b, head h):
    h_t = exp(dt_t·A_h)·h_{t-1} + dt_t·x_t ⊗ B_t        (state: (P, N))
    y_t = C_t·h_t + D_h·x_t
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_CHUNK = 128
NEG_INF = -1e30


def _ssd_kernel(a_ref, d_ref, x_ref, dt_ref, b_ref, c_ref,
                y_ref, state_out_ref, state_ref, *,
                n_chunks: int, chunk: int, has_d: bool):
    h_idx = pl.program_id(1)
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a_h = a_ref[h_idx]                                   # scalar
    x = x_ref[0, :, 0, :].astype(jnp.float32)            # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # (L,)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)           # (L, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)           # (L, N)

    a = dt * a_h                                         # (L,) ≤ 0
    cum = jnp.cumsum(a)                                  # (L,)
    # --- intra-chunk (quadratic dual form) ---
    g = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    expo = cum[:, None] - cum[None, :]
    expo = jnp.where(j_pos <= i_pos, expo, NEG_INF)
    m = g * jnp.exp(expo)                                # decay-masked
    xdt = x * dt[:, None]                                # (L, P)
    y = jax.lax.dot_general(m, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # --- inter-chunk: contribution of the carried state ---
    s0 = state_ref[...]                                  # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, s0, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (L, P)
    if has_d:
        y += d_ref[h_idx] * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # --- state update ---
    total = cum[chunk - 1]
    w = dt * jnp.exp(total - cum)                        # (L,)
    state_ref[...] = (jnp.exp(total) * s0
                      + jax.lax.dot_general(
                          x * w[:, None], bm, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    B: jnp.ndarray, C: jnp.ndarray,
                    D: Optional[jnp.ndarray] = None, *,
                    chunk: int = DEF_CHUNK,
                    interpret: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P); dt (B,S,H); A (H,); B,C (B,S,G,N); D (H,)|None.

    Returns y (B,S,H,P) and final state (B,H,P,N).  S % chunk == 0.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    group = h // g
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    has_d = D is not None
    d_arg = D if has_d else jnp.zeros((h,), jnp.float32)
    grid = (b, h, n_chunks)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk,
                          has_d=has_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM),   # A
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM),   # D
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ic: (b_, ic, h_, 0)),    # x
            pl.BlockSpec((1, chunk, 1),
                         lambda b_, h_, ic: (b_, ic, h_)),       # dt
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, h_, ic: (b_, ic, h_ // group, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, h_, ic: (b_, ic, h_ // group, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ic: (b_, ic, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), d_arg.astype(jnp.float32), x, dt, B, C)
    return y, state
